"""Key hashing + branchless multiplicative pattern generation (paper §4.2).

TPU adaptation notes
--------------------
The paper hashes 64-bit keys with xxHash64 and derives fingerprint bits by
multiplying the base hash with compile-time-inlined odd constants ("salts").
The TPU VPU is a 32-bit machine (no native u64), so:

* keys are carried in ``u64x2`` format — an array of shape ``(..., 2)`` of
  ``uint32`` holding ``[hi, lo]`` words of the conceptual 64-bit key;
* the base hash is an *exact* xxHash32 of the 8-byte little-endian key
  (the specialization of xxHash32 for inputs < 16 bytes), evaluated twice
  with independent seeds to recover 64 bits of fingerprint entropy
  (one stream selects the block, the other generates bit patterns);
* fingerprint bits use multiplicative (mul-shift) hashing
  [Dietzfelbinger et al. 1997], i.e. ``bit = (h * salt) >> (32 - log2(S))``.

Salts live in a module-level table and are indexed with *Python* integers at
trace time, so XLA sees them as literal constants folded into the kernel —
the exact analogue of the paper's C++ template-metaprogramming trick that
inlines multipliers into the generated SASS.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# xxHash32 constants
# ---------------------------------------------------------------------------
_P1 = np.uint32(2654435761)
_P2 = np.uint32(2246822519)
_P3 = np.uint32(3266489917)
_P4 = np.uint32(668265263)
_P5 = np.uint32(374761393)

# Independent hash streams (seeds) for block selection vs. pattern generation.
SEED_PATTERN = np.uint32(0xCAFEBABE)
SEED_BLOCK = np.uint32(0xDEADBEEF)
SEED_AUX = np.uint32(0x9E3779B9)

# ---------------------------------------------------------------------------
# Salt table — odd 32-bit multiplicative constants, fixed at import time.
# ---------------------------------------------------------------------------
MAX_SALTS = 96


def _make_salts(n: int, seed: int = 0xB100F) -> np.ndarray:
    rng = np.random.RandomState(seed)
    salts = rng.randint(0, 2**31, size=n, dtype=np.int64).astype(np.uint64)
    salts = (salts * 2 + 1).astype(np.uint32)  # force odd
    # make sure high bits are well mixed: xor-fold a second stream
    salts ^= rng.randint(0, 2**31, size=n, dtype=np.int64).astype(np.uint32) << np.uint32(1)
    return salts | np.uint32(1)


SALTS = _make_salts(MAX_SALTS)                      # fingerprint bit salts
WORD_SALTS = _make_salts(MAX_SALTS, seed=0x5EC70)   # BBF word-selection salts
GROUP_SALTS = _make_salts(MAX_SALTS, seed=0x6709)   # CSBF group->word salts


def _u32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def rotl32(x: jnp.ndarray, r: int) -> jnp.ndarray:
    """Rotate-left on uint32 (r is a Python int — static)."""
    r = int(r) % 32
    if r == 0:
        return x
    x = _u32(x)
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def xxh32_u64x2(keys: jnp.ndarray, seed=SEED_PATTERN) -> jnp.ndarray:
    """Exact xxHash32 of an 8-byte (u64) key held as uint32 ``[hi, lo]`` pairs.

    ``keys``: (..., 2) uint32. Returns (...,) uint32.

    This is the xxHash32 algorithm specialized for len==8: the accumulator
    starts at ``seed + PRIME5 + len`` and consumes the two 4-byte lanes of
    the little-endian u64 (lo word first), followed by the final avalanche.
    """
    keys = _u32(keys)
    hi = keys[..., 0]
    lo = keys[..., 1]
    acc = _u32(seed) + _P5 + np.uint32(8)
    for lane in (lo, hi):  # little-endian order: low word first
        acc = acc + lane * _P3
        acc = rotl32(acc, 17) * _P4
    # avalanche
    acc = acc ^ (acc >> np.uint32(15))
    acc = acc * _P2
    acc = acc ^ (acc >> np.uint32(13))
    acc = acc * _P3
    acc = acc ^ (acc >> np.uint32(16))
    return acc


def xxh32_u64x2_pair(keys: jnp.ndarray):
    """Fused dual-seed xxHash32 — both hash streams from ONE wide mix.

    Returns ``(xxh32_u64x2(keys, SEED_PATTERN), xxh32_u64x2(keys, SEED_BLOCK))``
    bit-for-bit, but computes the seed-independent lane products
    ``lane * PRIME3`` once and feeds them to both accumulators. The seed
    only enters xxHash32 through the accumulator initial value, so the
    per-lane multiplies (the expensive u32 ops on a 32-bit VPU) are shared:
    2 of the 8 multiplies drop out relative to two independent evaluations.
    This is the ``mix="cheap"`` engine option (paper §4.2's fused
    multi-hash): identical uint32 arithmetic, merely restructured, which is
    what keeps every kernel built on it bit-exact with ``mix="full"``.
    """
    keys = _u32(keys)
    hi = keys[..., 0]
    lo = keys[..., 1]
    plo = lo * _P3                       # seed-independent lane products,
    phi = hi * _P3                       # computed once for both streams
    outs = []
    for seed in (SEED_PATTERN, SEED_BLOCK):
        acc = _u32(seed) + _P5 + np.uint32(8)
        for lanep in (plo, phi):         # little-endian order: low word first
            acc = rotl32(acc + lanep, 17) * _P4
        acc = acc ^ (acc >> np.uint32(15))
        acc = acc * _P2
        acc = acc ^ (acc >> np.uint32(13))
        acc = acc * _P3
        acc = acc ^ (acc >> np.uint32(16))
        outs.append(acc)
    return outs[0], outs[1]


def xxh32_u32(keys: jnp.ndarray, seed=SEED_PATTERN) -> jnp.ndarray:
    """Exact xxHash32 of a 4-byte key (single uint32 lane)."""
    keys = _u32(keys)
    acc = _u32(seed) + _P5 + np.uint32(4)
    acc = acc + keys * _P3
    acc = rotl32(acc, 17) * _P4
    acc = acc ^ (acc >> np.uint32(15))
    acc = acc * _P2
    acc = acc ^ (acc >> np.uint32(13))
    acc = acc * _P3
    acc = acc ^ (acc >> np.uint32(16))
    return acc


def mulshift(h: jnp.ndarray, salt: np.uint32, bits: int) -> jnp.ndarray:
    """Multiplicative hash: top ``bits`` bits of ``h * salt`` (universal family).

    ``salt`` and ``bits`` are Python-level constants — folded into the
    generated code at trace time (the paper's salt-inlining analogue).
    """
    if bits == 0:
        return jnp.zeros_like(_u32(h))
    return (_u32(h) * np.uint32(salt)) >> np.uint32(32 - bits)


def block_index(h_block: jnp.ndarray, n_blocks: int) -> jnp.ndarray:
    """Map the block-stream hash to ``[0, n_blocks)``; n_blocks must be pow2."""
    assert n_blocks & (n_blocks - 1) == 0, "n_blocks must be a power of two"
    return _u32(h_block) & np.uint32(n_blocks - 1)


def hash_keys(keys: jnp.ndarray):
    """Return the (pattern, block) hash-stream pair for u64x2 or u32 keys."""
    if keys.ndim >= 1 and keys.shape[-1] == 2 and keys.dtype == jnp.uint32:
        return (xxh32_u64x2(keys, SEED_PATTERN), xxh32_u64x2(keys, SEED_BLOCK))
    return (xxh32_u32(keys, SEED_PATTERN), xxh32_u32(keys, SEED_BLOCK))


def mix_rows(mat: jnp.ndarray) -> jnp.ndarray:
    """Hash rows of uint32 tokens to u64x2 keys, fully on device.

    ``mat``: (..., w) uint32. Returns (..., 2) uint32. The column loop is
    a *trace-time* Python loop over the (small, static) row width — FNV/
    Fibonacci-style mixing fuses into a handful of whole-batch vector ops,
    so callers like the n-gram guard hash an entire decode batch per step
    with zero host-side per-row work."""
    mat = jnp.asarray(mat, jnp.uint32)
    h1 = jnp.full(mat.shape[:-1], 0x811C9DC5, jnp.uint32)
    h2 = jnp.full(mat.shape[:-1], 0x9E3779B9, jnp.uint32)
    for j in range(mat.shape[-1]):        # static unroll over columns
        c = mat[..., j]
        h1 = (h1 ^ c) * jnp.uint32(16777619)
        h2 = (h2 + c) * jnp.uint32(2246822519)
        h2 = h2 ^ (h2 >> jnp.uint32(13))
    h1 = h1 ^ (h1 >> jnp.uint32(16))
    return jnp.stack([h1, h2], axis=-1)


# ---------------------------------------------------------------------------
# Host-side reference (numpy, used by tests to cross-check the jnp path)
# ---------------------------------------------------------------------------

def xxh32_u64_numpy(keys_u64: np.ndarray, seed: int = int(SEED_PATTERN)) -> np.ndarray:
    keys_u64 = keys_u64.astype(np.uint64)
    lo = (keys_u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys_u64 >> np.uint64(32)).astype(np.uint32)
    with np.errstate(over="ignore"):
        acc = np.uint32(seed) + _P5 + np.uint32(8)
        for lane in (lo, hi):
            acc = acc + lane * _P3
            acc = ((acc << np.uint32(17)) | (acc >> np.uint32(15))) * _P4
        acc = acc ^ (acc >> np.uint32(15))
        acc = acc * _P2
        acc = acc ^ (acc >> np.uint32(13))
        acc = acc * _P3
        acc = acc ^ (acc >> np.uint32(16))
    return acc


def u64x2_from_u64(keys_u64: np.ndarray) -> np.ndarray:
    """Host helper: pack np.uint64 keys into (n, 2) uint32 [hi, lo]."""
    keys_u64 = np.asarray(keys_u64, dtype=np.uint64)
    hi = (keys_u64 >> np.uint64(32)).astype(np.uint32)
    lo = (keys_u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return np.stack([hi, lo], axis=-1)


def random_u64x2(n: int, seed: int = 0) -> np.ndarray:
    """Host helper: n distinct-ish random u64 keys in u64x2 format.

    Keys are drawn from the *insert* keyspace — the top bit of the u64 is
    always clear. The complementary range (top bit set) is reserved for
    ``probe_u64x2``, so FPR probes are structurally disjoint from any key
    set generated here (see ``Filter.measure_fpr``).
    """
    rng = np.random.RandomState(seed)
    lo = rng.randint(0, 2**32, size=n, dtype=np.uint64)
    hi = rng.randint(0, 2**31, size=n, dtype=np.uint64)  # top bit reserved
    return u64x2_from_u64((hi << np.uint64(32)) | lo)


def probe_u64x2(n: int, seed: int = 0) -> np.ndarray:
    """n random u64 probe keys from the reserved range (top bit set).

    Disjoint by construction from every ``random_u64x2`` draw — the
    right source for empirical FPR measurements, where a probe that
    collides with an inserted key would misreport a true positive as a
    false one."""
    rng = np.random.RandomState(seed ^ 0x5EED)
    lo = rng.randint(0, 2**32, size=n, dtype=np.uint64)
    hi = rng.randint(0, 2**31, size=n, dtype=np.uint64) | np.uint64(1 << 31)
    return u64x2_from_u64((hi << np.uint64(32)) | lo)
