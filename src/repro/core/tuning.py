"""(Θ, Φ, probe, depth, segments) autotuner — the paper's Table 1/2 grid
search as a library, extended to every axis the kernels expose.

The paper's headline empirical result is that the optimal vectorization
layout depends on (operation, block size, residency). ``tune_layout`` sweeps
the valid (Θ, Φ) grid for a (spec, tile) and returns the fastest layout;
``tune_plan`` additionally picks the probe strategy (per-key loop vs
whole-tile gather), the HBM DMA pipeline depth and the partitioned-add
segment count, returning a :class:`Plan` that `api.backends` threads into
the kernels:

* ``mode="measure"`` times the Pallas kernels, best-of-``repeats`` after a
  warmup run to de-noise the grid (meaningful on real TPU; in interpret
  mode the ratios reflect schedule structure);
* ``mode="structural"`` (default) ranks the candidate grid — now including
  the cooperation axes (``coop``: lane-group subtile probing, ``mix``:
  fused cheap double-hash) — by the calibrated performance model's
  predicted cost (``repro.perfmodel``: bytes moved / flops / launch and
  schedule overhead per bulk op, converted to time through the measured
  machine calibration). The original §4.1 structural scorers remain for
  ``tune_layout`` (the paper's empirical Θ̂ tie-breaks) and diagnostics.

Results are cached per (spec, op, mode, tile[, regime]) in-process AND in a
disk-persisted JSON cache (``REPRO_TUNING_CACHE`` env var, default
``~/.cache/repro/tuning.json``) so a fleet of processes pays the grid
search once. The cache key includes every axis that changes the valid
candidate set — in particular ``tile``: a layout tuned for tile=256 is NOT
valid for tile=8 (Θ must divide the tile), which is why tile lives in the
key and every candidate is re-validated against it.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashing as H
from repro.core.variants import FilterSpec
from repro.kernels.sbf import (DEFAULT_TILE, DMA_DEPTHS, Layout,
                               VMEM_FILTER_BYTES, default_layout)

TUNABLE_DEPTHS = (2, 4, 8)        # the sweep; depth=1 (serial) is debug-only
TUNABLE_SEGMENTS = (4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class Plan:
    """One tuned kernel configuration (static, hashable — carried through
    `api.BackendOptions` and closed over by the cached-jit dispatch)."""

    layout: Layout
    probe: str = "gather"          # "loop" | "gather" (vmem-regime phase 2)
    depth: int = 2                 # HBM contains DMA pipeline depth
    n_segments: int = 8            # partitioned bulk-add grid width
    coop: str = "none"             # "none" | "subtile" lane-group probing
    mix: str = "full"              # "full" | "cheap" fused double-hash

    def to_dict(self) -> dict:
        return {"theta": self.layout.theta, "phi": self.layout.phi,
                "probe": self.probe, "depth": self.depth,
                "n_segments": self.n_segments, "coop": self.coop,
                "mix": self.mix}

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        return cls(Layout(int(d["theta"]), int(d["phi"])), str(d["probe"]),
                   int(d["depth"]), int(d["n_segments"]),
                   str(d.get("coop", "none")), str(d.get("mix", "full")))


# ---------------------------------------------------------------------------
# Disk-persisted cache
# ---------------------------------------------------------------------------

def cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNING_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "tuning.json"))


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def _store_disk(key: str, value: dict) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        data = _load_disk()
        data[key] = value
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                       # cache is an optimization, never an error


def _plan_key(spec: FilterSpec, op: str, regime: str, mode: str,
              tile: int, bank: int = 1, coop: str = "auto",
              mix: str = "auto") -> str:
    # The backend is part of the key: measure-mode timings taken in CPU
    # interpret mode must never pin a plan for a real TPU run (the same
    # stale-key class of bug as omitting tile). ``bank`` joins the key for
    # the same reason — a B-member bank shifts the loop/gather crossover
    # (B× the gather index space, B× the RMW working set) and must never
    # silently reuse a plan tuned for the scalar filter.
    # ``coop``/``mix`` join the key because a PINNED cooperation or mix
    # axis restricts the candidate grid: a plan tuned under coop="none"
    # must never answer a coop="subtile" query (and vice versa) — the same
    # stale-key bug class again. The "plan2" version prefix retires every
    # pre-cooperation cache entry wholesale: old entries lack the
    # coop/mix fields and were ranked by the structural scorer, not the
    # perfmodel predictor.
    # ``str(spec)`` carries the variant name AND every variant-specific
    # geometry field (FilterSpec.__str__ spells cuckoo slot geometry out),
    # so same-m specs of different variants never share an entry.
    base = (f"plan2|{jax.default_backend()}|{spec}|{op}|{regime}|{mode}"
            f"|tile{tile}|coop:{coop}|mix:{mix}")
    return base if bank == 1 else f"{base}|bank{bank}"


# ---------------------------------------------------------------------------
# (Θ, Φ) layout grid
# ---------------------------------------------------------------------------

def valid_layouts(spec: FilterSpec, tile: int = DEFAULT_TILE) -> List[Layout]:
    s = spec.s
    out = []
    for theta in (1, 2, 4, 8, 16):
        if tile % theta:
            continue
        for phi in (1, 2, 4, 8, 16, 32):
            if phi <= s and s % phi == 0 and theta * phi <= max(s, 8):
                out.append(Layout(theta, phi))
    return out


def structural_score(spec: FilterSpec, lay: Layout, op: str) -> float:
    """Lower is better. Mirrors §4.1: wide loads amortize issue cost; too
    much Θ under-utilizes lanes for lookups but tightens RMW windows for
    adds (the paper's Θ̂ rules, encoded as a soft preference)."""
    s = spec.s
    loads = s // lay.phi                      # load instructions per block
    steps = max(s // (lay.theta * lay.phi), 1)
    score = loads + 0.5 * steps
    if op == "contains":
        target = max(1, spec.block_bits // 256)
        score += 0.25 * abs(lay.theta - target)
    else:                                     # add: fully horizontal wins
        score += 0.25 * (s - min(lay.theta * lay.phi, s)) / max(s, 1)
        score += 0.1 * loads
    return score


def probe_schedule_steps(spec: FilterSpec, lay: Layout, op: str, tile: int,
                         probe: str, bank: int = 1) -> float:
    """Interpret-mode schedule-step count of one key tile's phase 2.

    loop:   (tile/Θ) trips, each issuing s/Φ loads + 1 fused compare (or
            s/Φ RMW pairs for add) — the per-key scalar walk.
    gather: a constant number of whole-tile vector ops — index build,
            ONE gather, ONE fused compare for contains; sort (log²-depth
            bitonic analogue), segmented scan, gather, scatter for add.

    ``bank``: a B-member bank widens the resident word array B×. The loop
    probe's dynamic-slice loads then stride across the whole bank (one
    address stream per key, locality decaying with bank depth); the gather
    probe only grows its index space (one extra vector op worth per
    doubling). Both are soft log2 terms — the fixed per-trip structure is
    unchanged.
    """
    import math
    lg_b = math.log2(max(bank, 1))
    if probe == "loop":
        per_trip = spec.s // lay.phi + (1 if op == "contains" else
                                        spec.s // lay.phi)
        return (tile // lay.theta) * per_trip * (1.0 + 0.05 * lg_b)
    if op == "contains":
        return 3.0 + 0.25 * lg_b
    lg = max(math.log2(max(tile, 2)), 1.0)
    # sort + segmented scan + gather + scatter (+ bank index widening)
    return 2.0 * lg + 4.0 + 0.25 * lg_b


def depth_structural_score(spec: FilterSpec, depth: int) -> float:
    """Stall model for the HBM contains pipeline: a row DMA costs a fixed
    issue latency plus the row transfer; each in-flight slot hides one
    row's compute. Deeper pipelines win for small rows (latency-bound) and
    waste scratch for large rows (bandwidth-bound)."""
    s = spec.s
    latency = 32.0 + s             # fixed DMA latency + transfer (words)
    compute = float(s)             # per-row test cost
    stall = max(latency - (depth - 1) * compute, 0.0)
    return stall + compute + 0.1 * depth * s   # + scratch pressure tiebreak


def segments_structural_score(spec: FilterSpec, n_segments: int) -> float:
    """Prefer the smallest grid whose exclusive segment fits the VMEM
    budget (each partitioned-grid step pins one segment)."""
    if spec.n_blocks % n_segments or spec.storage_words % n_segments:
        return float("inf")
    seg_bytes = spec.storage_words * 4 / n_segments
    penalty = 0.0 if seg_bytes <= VMEM_FILTER_BYTES else seg_bytes
    return penalty + n_segments    # grid-launch overhead tiebreak


def _measure(spec: FilterSpec, op: str, n_keys: int, repeats: int,
             **kw) -> float:
    """Best-of-``repeats`` post-warmup wall time.

    A single timed run is dominated by scheduler/allocator noise at the
    microsecond scales the grid search discriminates on; the *minimum* over
    k runs is the standard noise-floor estimator (any positive perturbation
    only raises a sample, never lowers it)."""
    from repro.kernels import ops
    keys = jnp.asarray(H.random_u64x2(n_keys, seed=7))
    filt = jnp.zeros((spec.n_words,), jnp.uint32)
    if op == "contains":
        fn = lambda: ops.bloom_contains(spec, filt, keys, **kw)
    else:
        fn = lambda: ops.bloom_add(spec, filt, keys, **kw)
    jax.block_until_ready(fn())                       # warmup (compile)
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


@functools.lru_cache(maxsize=256)
def tune_layout(spec: FilterSpec, op: str = "contains",
                mode: str = "structural", n_keys: int = 1024,
                repeats: int = 3, tile: int = DEFAULT_TILE
                ) -> Tuple[Layout, List[Tuple[str, float]]]:
    """Returns (best layout, [(layout-name, score/time) ...]).

    ``tile`` is part of the cache key AND the validation constraint: Θ must
    divide the tile, so the candidate grid differs per tile and a layout
    tuned for one tile must never be silently reused for another.
    ``repeats`` (measure mode) de-noises the grid search: each candidate is
    timed ``repeats`` times post-warmup and scored by its best run."""
    assert op in ("contains", "add")
    cands = []
    for lay in valid_layouts(spec, tile):
        try:
            cands.append(lay.validate(spec, tile))
        except AssertionError:
            continue
    cands = sorted(set(cands), key=lambda l: (l.theta, l.phi))
    if not cands:
        return default_layout(spec, op), []
    if mode == "structural":
        scored = [(str(l), structural_score(spec, l, op)) for l in cands]
    else:
        scored = [(str(l), _measure(spec, op, n_keys, repeats,
                                    layout=l, tile=tile, probe="loop"))
                  for l in cands]
    best_name, _ = min(scored, key=lambda kv: kv[1])
    best = next(l for l in cands if str(l) == best_name)
    return best, sorted(scored, key=lambda kv: kv[1])


# ---------------------------------------------------------------------------
# Full-plan sweep: probe strategy x depth x segments (+ the layout grid)
# ---------------------------------------------------------------------------

def _model_candidates(coop: str, mix: str):
    """The (probe, coop, mix) candidate grid under optional pinning.
    coop="subtile" supersedes the probe strategy in the kernels, so
    cooperative candidates are canonicalized to probe="gather" — one
    spelling per distinct schedule, no duplicate cache entries. Order
    breaks predicted-cost ties toward the non-coop baseline and the full
    mix's cheap sibling is ranked by its strictly-lower flop count."""
    coops = ("none", "subtile") if coop == "auto" else (coop,)
    mixes = ("cheap", "full") if mix == "auto" else (mix,)
    out = []
    for c in coops:
        probes = ("gather", "loop") if c == "none" else ("gather",)
        for p in probes:
            for m in mixes:
                out.append((p, c, m))
    return out


@functools.lru_cache(maxsize=256)
def tune_plan(spec: FilterSpec, op: str = "contains", regime: str = "vmem",
              mode: str = "structural", n_keys: int = 1024, repeats: int = 3,
              tile: int = DEFAULT_TILE, bank: int = 1, coop: str = "auto",
              mix: str = "auto") -> Plan:
    """Pick (layout, probe, coop, mix, depth, n_segments) for a
    (spec, op, regime).

    Checks the disk cache first; a miss runs the sweep and persists the
    winner, so every process on a host converges to one tuned plan per
    configuration. The default (non-measure) mode ranks the full
    (layout x probe x coop x mix x depth) candidate grid by the
    calibrated performance model's predicted cost
    (``perfmodel.predict_config_us``) — the structural scorers survive as
    the legacy ``tune_layout`` path and for diagnostics, but plan
    selection is model-driven.

    ``coop``/``mix``: ``"auto"`` sweeps both axes; a pinned value
    restricts the grid (and keys the cache entry — see ``_plan_key``).
    ``mode="measure"`` still times the actual kernels for the probe/depth
    axes and keeps the pinned-or-baseline coop/mix (measuring the
    cooperative kernels adds nothing off-TPU where every path is
    interpret-mode).

    ``bank`` keys the plan to a B-member :class:`FilterBank` workload: the
    model scales the loop probe's per-trip cost by the bank's deeper
    working set while the gather probe stays whole-tile constant.
    """
    assert op in ("contains", "add") and bank >= 1
    from repro.kernels.sbf import COOPS, DMA_DEPTHS, MIXES, PROBES
    assert coop == "auto" or coop in COOPS, coop
    assert mix == "auto" or mix in MIXES, mix
    key = _plan_key(spec, op, regime, mode, tile, bank, coop, mix)
    cached = _load_disk().get(key)
    if cached is not None:
        try:
            plan = Plan.from_dict(cached)
            # Re-validate against the CURRENT constraint sets — a stale
            # entry from an older library version (depth no longer in the
            # sweep, renamed probe/coop/mix, Θ that stopped dividing the
            # tile) must re-tune, not crash every probe="auto" call until
            # the user deletes the cache file by hand.
            if (plan.probe in PROBES and plan.depth in DMA_DEPTHS
                    and plan.n_segments in TUNABLE_SEGMENTS
                    and plan.coop in COOPS and plan.mix in MIXES):
                plan.layout.validate(spec, tile)
                return plan
        except (KeyError, ValueError, TypeError, AssertionError):
            pass                   # stale/corrupt entry: re-tune
    layout, _ = tune_layout(spec, op, mode=mode, n_keys=n_keys,
                            repeats=repeats, tile=tile)
    if mode == "measure":
        if regime == "vmem":
            t_loop = _measure(spec, op, n_keys, repeats, layout=layout,
                              tile=tile, probe="loop", regime="vmem")
            t_gather = _measure(spec, op, n_keys, repeats, tile=tile,
                                probe="gather", regime="vmem")
            probe = "gather" if t_gather <= t_loop else "loop"
        else:
            probe = "gather"
        if regime == "hbm" and op == "contains":
            timed = {d: _measure(spec, op, n_keys, repeats, regime="hbm",
                                 tile=tile, depth=d) for d in TUNABLE_DEPTHS}
            depth = min(timed, key=timed.get)
        else:
            depth = min(TUNABLE_DEPTHS,
                        key=lambda d: depth_structural_score(spec, d))
        best_coop = coop if coop != "auto" else "none"
        best_mix = mix if mix != "auto" else "full"
    else:
        from repro import perfmodel as PM
        calib = PM.get_calibration()

        def score(p, c, m, d):
            t = PM.predict_config_us(spec, op, regime, layout=layout,
                                     probe=p, coop=c, mix=m, depth=d,
                                     tile=tile, bank=bank, calib=calib)
            flops = PM.op_cost(spec, op, regime, layout=layout, probe=p,
                               coop=c, mix=m, depth=d, tile=tile,
                               n_keys=tile, bank=bank).flops
            return (t, flops)      # flop tie-break: cheap mix wins ties

        cands = _model_candidates(coop, mix)
        probe, best_coop, best_mix = min(
            cands, key=lambda pcm: score(*pcm, 2))
        depth = min(TUNABLE_DEPTHS,
                    key=lambda d: score(probe, "none", best_mix, d))
    n_segments = min(TUNABLE_SEGMENTS,
                     key=lambda ns: segments_structural_score(spec, ns))
    plan = Plan(layout=layout, probe=probe, depth=depth,
                n_segments=n_segments, coop=best_coop, mix=best_mix)
    _store_disk(key, plan.to_dict())
    return plan
