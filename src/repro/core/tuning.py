"""(Θ, Φ) layout autotuner — the paper's Table 1/2 grid search as a library.

The paper's headline empirical result is that the optimal vectorization
layout depends on (operation, block size, residency). ``tune_layout`` sweeps
the valid (Θ, Φ) grid for a spec and returns the fastest layout:

* ``mode="measure"`` times the Pallas kernels, best-of-``repeats`` after a
  warmup run to de-noise the grid (meaningful on real TPU; in interpret
  mode the ratios reflect schedule structure);
* ``mode="structural"`` scores layouts analytically (loads per block,
  strided steps, vector width — the §4.1 derivations) and applies the
  paper's empirical tie-breaks (Θ̂_c = max(1, B/256), Θ̂_a = s), giving a
  deterministic offline choice for dry-run/compile-only environments.

Results are cached per (spec, op, mode).
"""
from __future__ import annotations

import functools
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashing as H
from repro.core.variants import FilterSpec
from repro.kernels.sbf import Layout, default_layout


def valid_layouts(spec: FilterSpec, tile: int = 256) -> List[Layout]:
    s = spec.s
    out = []
    for theta in (1, 2, 4, 8, 16):
        if tile % theta:
            continue
        for phi in (1, 2, 4, 8, 16, 32):
            if phi <= s and s % phi == 0 and theta * phi <= max(s, 8):
                out.append(Layout(theta, phi))
    return out


def structural_score(spec: FilterSpec, lay: Layout, op: str) -> float:
    """Lower is better. Mirrors §4.1: wide loads amortize issue cost; too
    much Θ under-utilizes lanes for lookups but tightens RMW windows for
    adds (the paper's Θ̂ rules, encoded as a soft preference)."""
    s = spec.s
    loads = s // lay.phi                      # load instructions per block
    steps = max(s // (lay.theta * lay.phi), 1)
    score = loads + 0.5 * steps
    if op == "contains":
        target = max(1, spec.block_bits // 256)
        score += 0.25 * abs(lay.theta - target)
    else:                                     # add: fully horizontal wins
        score += 0.25 * (s - min(lay.theta * lay.phi, s)) / max(s, 1)
        score += 0.1 * loads
    return score


def _measure(spec: FilterSpec, lay: Layout, op: str, n_keys: int,
             repeats: int = 3) -> float:
    """Best-of-``repeats`` post-warmup wall time.

    A single timed run is dominated by scheduler/allocator noise at the
    microsecond scales the grid search discriminates on; the *minimum* over
    k runs is the standard noise-floor estimator (any positive perturbation
    only raises a sample, never lowers it)."""
    from repro.kernels import ops
    keys = jnp.asarray(H.random_u64x2(n_keys, seed=7))
    filt = jnp.zeros((spec.n_words,), jnp.uint32)
    if op == "contains":
        fn = lambda: ops.bloom_contains(spec, filt, keys, layout=lay)
    else:
        fn = lambda: ops.bloom_add(spec, filt, keys, layout=lay)
    jax.block_until_ready(fn())                       # warmup (compile)
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


@functools.lru_cache(maxsize=128)
def tune_layout(spec: FilterSpec, op: str = "contains",
                mode: str = "structural", n_keys: int = 1024,
                repeats: int = 3
                ) -> Tuple[Layout, List[Tuple[str, float]]]:
    """Returns (best layout, [(layout-name, score/time) ...]).

    ``repeats`` (measure mode) de-noises the grid search: each candidate is
    timed ``repeats`` times post-warmup and scored by its best run."""
    assert op in ("contains", "add")
    cands = valid_layouts(spec)
    if not cands:
        return default_layout(spec, op), []
    if mode == "structural":
        scored = [(str(l), structural_score(spec, l, op)) for l in cands]
    else:
        scored = [(str(l), _measure(spec, l, op, n_keys, repeats))
                  for l in cands]
    best_name, _ = min(scored, key=lambda kv: kv[1])
    best = next(l for l in cands if str(l) == best_name)
    return best, sorted(scored, key=lambda kv: kv[1])
