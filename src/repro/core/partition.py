"""Radix partitioning of keys by filter segment — the TPU ownership model.

On the GPU, concurrent inserts from many SMs into shared blocks are made safe
by ``atomicOr`` and made *fast* by the L1 temporal coalescer (paper §2.2/§5.2).
TPUs have neither; instead we adopt the strategy of the paper's own CPU
baseline (Schmidt et al. [30], radix partitioning): bucket the keys by the
filter segment their block falls in, so that

* each Pallas grid step (or each device of a sharded filter) owns one
  segment exclusively -> plain read-modify-write, no atomics;
* every access within a step hits one VMEM-resident segment -> the
  cache-resident fast path applies even to HBM-sized filters.

Both a host-side (numpy, exact capacity) and a jit-compatible (fixed
capacity, validity-masked) implementation are provided.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hashing as H
from repro.core.variants import FilterSpec


class JitPartition(NamedTuple):
    """Result of :func:`partition_jit` (all device arrays, fixed shapes).

    ``keep`` marks the keys that landed inside their segment's capacity;
    ``overflow`` counts the ones that did NOT (they are absent from
    ``keys_by_seg`` and the caller MUST handle them — retry with a larger
    capacity, or apply a residual pass over ``~keep``). Silent key loss
    through this path is a bug, not a policy.
    """

    keys_by_seg: jnp.ndarray   # (n_segments, capacity, 2) uint32
    valid: jnp.ndarray         # (n_segments, capacity) uint8
    keep: jnp.ndarray          # (n,) bool — key survived into its segment
    overflow: jnp.ndarray      # () int32 — number of dropped keys
    rank: jnp.ndarray          # (n,) int32 — key's slot within its bucket


def segment_ids(spec: FilterSpec, keys: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    """Segment owning each key's block. Segments are contiguous block ranges."""
    assert spec.n_blocks % n_segments == 0
    blocks_per_seg = spec.n_blocks // n_segments
    h2 = H.xxh32_u64x2(keys, H.SEED_BLOCK) if keys.shape[-1] == 2 else H.xxh32_u32(keys, H.SEED_BLOCK)
    blk = H.block_index(h2, spec.n_blocks)
    return (blk // jnp.uint32(blocks_per_seg)).astype(jnp.int32)


def partition_host(spec: FilterSpec, keys: np.ndarray, n_segments: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side exact partition.

    Returns (keys_by_seg [S, cap, 2] uint32, valid [S, cap] uint8,
    counts [S] int64). cap = max per-segment count, rounded up to 8 for
    sublane alignment.
    """
    keys = np.asarray(keys, dtype=np.uint32)
    seg = np.asarray(segment_ids(spec, jnp.asarray(keys), n_segments))
    counts = np.bincount(seg, minlength=n_segments)
    cap = max(int(counts.max()), 1)
    cap = (cap + 7) & ~7
    out = np.zeros((n_segments, cap, 2), dtype=np.uint32)
    valid = np.zeros((n_segments, cap), dtype=np.uint8)
    order = np.argsort(seg, kind="stable")
    sorted_keys = keys[order]
    offsets = np.concatenate([[0], np.cumsum(counts)])
    for sidx in range(n_segments):
        lo, hi = offsets[sidx], offsets[sidx + 1]
        out[sidx, : hi - lo] = sorted_keys[lo:hi]
        valid[sidx, : hi - lo] = 1
    return out, valid, counts


def partition_jit(spec: FilterSpec, keys: jnp.ndarray, n_segments: int,
                  capacity: int) -> JitPartition:
    """jit-compatible partition with static per-segment capacity.

    Keys beyond ``capacity`` in a segment do not fit the fixed-shape output;
    instead of silently dropping them this reports ``keep``/``overflow`` so
    dispatch (`kernels.ops.bloom_add_partitioned`) can escalate capacity
    (concrete callers) or run a vectorized residual pass over the dropped
    keys (traced callers). Capacity of mean * 4 is ~overflow-free for
    uniform hashes. Returns a :class:`JitPartition`.
    """
    seg = segment_ids(spec, keys, n_segments)                    # (n,)
    return route_by_id(keys, seg, n_segments, capacity)


def route_by_id(keys: jnp.ndarray, ids: jnp.ndarray, n_buckets: int,
                capacity: int) -> JitPartition:
    """jit-compatible scatter of flat keys into per-bucket batches.

    The generic form of :func:`partition_jit` with *caller-supplied* bucket
    ids — used by tenant routing (``repro.api.route``: ids are bank member
    indices) and by the hash-segment partition above (ids are segment
    owners). Fixed-shape output: (n_buckets, capacity, 2) keys plus a
    validity mask; same keep/overflow contract as :class:`JitPartition`
    (no silent key loss).
    """
    n = keys.shape[0]
    ids = jnp.asarray(ids, jnp.int32)
    # rank of each key within its bucket (stable): count predecessors
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    idx_in_run = jnp.arange(n) - jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    rank = jnp.zeros((n,), jnp.int32).at[order].set(idx_in_run.astype(jnp.int32))
    keep = rank < capacity
    slot = jnp.where(keep, ids * capacity + rank, n_buckets * capacity)  # overflow bin
    flat_keys = jnp.zeros((n_buckets * capacity + 1, 2), jnp.uint32
                          ).at[slot].set(keys, mode="drop")
    flat_valid = jnp.zeros((n_buckets * capacity + 1,), jnp.uint8
                           ).at[slot].set(1, mode="drop")
    return JitPartition(
        flat_keys[:-1].reshape(n_buckets, capacity, 2),
        flat_valid[:-1].reshape(n_buckets, capacity),
        keep,
        jnp.int32(n) - jnp.sum(keep).astype(jnp.int32),
        rank)
