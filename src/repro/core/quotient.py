"""Counting quotient filter — pure-jnp reference semantics.

The quotient filter (Bender et al.; the structure "High-Performance
Filters for GPUs" builds its two-level GQF on) is the one AMQ in the repo
that combines deletion with **lossless merge and resize**. A p-bit
fingerprint splits into ``q`` quotient bits (the home slot in a
``2^q``-slot table) and ``r`` remainder bits stored in the slot; three
metadata bits per slot — is_occupied / is_continuation / is_shifted —
encode how linear-probe displacement packed same-quotient *runs* into
maximal *clusters*. Because the metadata makes every stored fingerprint
exactly recoverable, ``merge`` is "decode both tables, rebuild from the
union" and ``resize`` is "decode, re-split p = q + r at the new boundary,
rebuild" — no raw keys anywhere (DESIGN.md §15).

TPU adaptation (mirroring ``core.fingerprint``'s conventions):

* the table is a flat ``(n_words,)`` uint32 array of ``n_slots`` slot
  lanes, ``slot_bits`` (8/16/32) each, packed little-endian; the top three
  lane bits are the metadata, the low ``r_bits`` the remainder;
* the physical layout is a **pure function of the stored fingerprint
  multiset**: bulk inserts decode the resident fingerprints, union them
  with the (batch-ordered, capacity-gated) new ones and rebuild the
  canonical layout with an all-vector scan — sort by rotated fingerprint,
  ``pos_j = j + cummax(rq_j - j)`` for the displacement, one scatter.
  This is the bulk-build schedule of the GPU quotient filters (and of the
  PR-3 ownership model: one sequential owner per table, sort-then-place),
  and it makes jnp and Pallas builds bit-identical *and* tile-size
  independent;
* wraparound is handled by the cycle lemma: with ``cnt[s]`` fingerprints
  homed at slot s, any argmin of ``cumsum(cnt - 1)`` is empty in the
  final layout, so building (and decoding) in coordinates rotated to
  start just past an empty slot never sees a wrapped cluster. Capacity is
  ``n_slots - 1`` — one slot always stays empty as the scan anchor;
* duplicates occupy one slot each (the *counting* behavior: multiplicity
  is multiset multiplicity), so adds/removes are NOT idempotent and bulk
  ops take a ``valid`` mask for padding — never repeat-key padding;
* an insert beyond capacity fails with an EXPLICIT per-key ``ok=False``
  (first-come-first-served in batch order), never a silent drop.

Every function is plain jnp/lax vector code, so the same helpers run
inside Pallas kernel bodies (interpret or compiled) and under
vmap/jit/scan — the single source of truth ``kernels.quotientfilter``
validates against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hashing as H
from repro.core.variants import (QF_META_BITS, QUOTIENT_SLOT_BITS,
                                 FilterSpec, _log2i)

QUOTIENT_ADD_TILE = 2048       # bulk-update chunk (decode + rebuild unit)
QUOTIENT_MAX_LOAD = 0.90       # practical linear-probe load ceiling

# fingerprint-stream salt: the same fixed member of the global salt table
# the cuckoo filter uses for ITS fingerprint stream, inlined at trace time
_FP_SALT = H.SALTS[0]

# empty-slot sentinel for sorted fingerprint streams: > any p<=31-bit
# fingerprint. A numpy scalar, NOT a jnp array — Pallas kernel bodies may
# not capture array constants, numpy scalars inline as literals.
_SENTINEL = np.uint32(0xFFFFFFFF)


def init(spec: FilterSpec) -> jnp.ndarray:
    assert spec.is_quotient
    return jnp.zeros((spec.n_words,), jnp.uint32)


# ---------------------------------------------------------------------------
# Hashing + slot packing
# ---------------------------------------------------------------------------

def quotient_hashes(spec: FilterSpec, keys: jnp.ndarray) -> jnp.ndarray:
    """(n,) uint32 p-bit fingerprints (p = q + r <= 31).

    One hash stream yields the whole fingerprint; the quotient/remainder
    split is pure bit arithmetic (``fp >> r`` / ``fp & (2^r - 1)``), which
    is what makes resize a re-split rather than a re-hash."""
    h1 = H.xxh32_u64x2(keys, H.SEED_PATTERN)
    return H.mulshift(h1, _FP_SALT, spec.fingerprint_bits)


def split_fp(spec: FilterSpec, fp: jnp.ndarray):
    """fingerprint -> (home slot (n,) int32, remainder (n,) uint32)."""
    r = spec.r_bits
    return ((fp >> jnp.uint32(r)).astype(jnp.int32),
            fp & jnp.uint32((1 << r) - 1))


def unpack_slots(spec: FilterSpec, words: jnp.ndarray) -> jnp.ndarray:
    """(..., n_words) packed words -> (..., n_slots) slot lanes.
    Slot j lives in word ``j // slots_per_word``, lane ``j % slots_per_word``
    (little-endian). The loop unrolls at trace time."""
    sb, spw = spec.slot_bits, spec.slots_per_word
    if spw == 1:
        return words
    mask = jnp.uint32((1 << sb) - 1)
    lanes = [(words >> jnp.uint32(sb * j)) & mask for j in range(spw)]
    return jnp.stack(lanes, axis=-1).reshape(*words.shape[:-1], -1)


def pack_slots(spec: FilterSpec, lanes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`unpack_slots`: (..., n_slots) -> (..., n_words)."""
    sb, spw = spec.slot_bits, spec.slots_per_word
    if spw == 1:
        return lanes
    x = lanes.reshape(*lanes.shape[:-1], -1, spw)
    acc = x[..., 0]
    for j in range(1, spw):
        acc = acc | (x[..., j] << jnp.uint32(sb * j))
    return acc


def _meta_masks(spec: FilterSpec):
    sb = spec.slot_bits
    occ = jnp.uint32(1 << (sb - 1))
    cont = jnp.uint32(1 << (sb - 2))
    shift = jnp.uint32(1 << (sb - 3))
    rem = jnp.uint32((1 << spec.r_bits) - 1)
    return occ, cont, shift, rem


def _fields(spec: FilterSpec, lanes: jnp.ndarray):
    """Per-slot metadata bits + remainder. ``in_use`` is the emptiness
    test: any metadata bit set (an element at its home slot carries
    is_occupied; a displaced one carries is_shifted)."""
    occ_m, cont_m, shift_m, rem_m = _meta_masks(spec)
    occ = (lanes & occ_m) != 0
    cont = (lanes & cont_m) != 0
    shifted = (lanes & shift_m) != 0
    in_use = occ | cont | shifted
    return occ, cont, shifted, in_use, lanes & rem_m


# ---------------------------------------------------------------------------
# Decode: recover the stored fingerprint multiset from the layout
# ---------------------------------------------------------------------------

def _rotated(n: int, anchor, arr: jnp.ndarray) -> jnp.ndarray:
    """View ``arr`` in scan coordinates starting just past ``anchor``
    (kernel-safe: iota + take, no dynamic roll)."""
    i = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    return jnp.take(arr, jnp.mod(i + anchor + 1, n), axis=0)


def _decode_rotated(spec: FilterSpec, lanes: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(fingerprints (n_slots,) uint32, valid (n_slots,) bool) in rotated
    scan order (arbitrary but deterministic; callers treat it as a
    multiset).

    The scan starts just past the first empty slot, so no cluster wraps:
    run starts (in_use & ~continuation) then correspond 1:1, in order, to
    occupied canonical slots — the i-th run's quotient is the position of
    the i-th occupied slot. ``searchsorted`` over the occupied prefix
    count inverts "i-th occupied" without a scatter."""
    n = spec.n_slots
    occ, cont, _, in_use, rem = _fields(spec, lanes)
    anchor = jnp.argmax(~in_use).astype(jnp.int32)     # first empty slot
    occ_r = _rotated(n, anchor, occ)
    cont_r = _rotated(n, anchor, cont)
    in_use_r = _rotated(n, anchor, in_use)
    rem_r = _rotated(n, anchor, rem)
    runs_upto = jnp.cumsum((in_use_r & ~cont_r).astype(jnp.int32))
    occ_upto = jnp.cumsum(occ_r.astype(jnp.int32))
    q_rot = jnp.searchsorted(occ_upto, runs_upto, side="left")
    q_abs = jnp.mod(q_rot.astype(jnp.int32) + anchor + 1, n)
    fp = (q_abs.astype(jnp.uint32) << jnp.uint32(spec.r_bits)) | rem_r
    return fp, in_use_r


def decode_fingerprints(spec: FilterSpec, table: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Public decode: (sorted fingerprints (n_slots,) uint32 with
    0xFFFFFFFF sentinels past the end, stored count () int32)."""
    fp, valid = _decode_rotated(spec, unpack_slots(spec, table))
    fps = jnp.sort(jnp.where(valid, fp, _SENTINEL))
    return fps, jnp.sum(valid.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Build: canonical layout from a fingerprint multiset
# ---------------------------------------------------------------------------

def _layout(spec: FilterSpec, fp: jnp.ndarray, valid: jnp.ndarray
            ) -> jnp.ndarray:
    """Slot lanes for the canonical layout of the multiset ``fp[valid]``
    (caller guarantees the valid count <= n_slots - 1).

    Rotation: with ``cnt[s]`` fingerprints homed at s, any argmin of
    ``cumsum(cnt - 1)`` is empty in the final layout (cycle lemma), so a
    scan started just past it needs no wraparound handling. In rotated
    coordinates the displaced position of the j-th smallest fingerprint is
    the associative-scan identity ``pos_j = j + cummax(rq_j - j)``; the
    metadata bits then read directly off the sorted stream (continuation:
    same quotient as the predecessor; shifted: pos != home)."""
    n, r = spec.n_slots, spec.r_bits
    L = fp.shape[0]
    occ_m, cont_m, shift_m, rem_m = _meta_masks(spec)
    q = (fp >> jnp.uint32(r)).astype(jnp.int32)
    vi = valid.astype(jnp.int32)
    cnt = jnp.zeros((n,), jnp.int32).at[jnp.where(valid, q, 0)].add(vi)
    anchor = jnp.argmin(jnp.cumsum(cnt - 1)).astype(jnp.int32)
    rq = jnp.mod(q - anchor - 1, n)
    rfp = jnp.where(valid,
                    (rq.astype(jnp.uint32) << jnp.uint32(r)) | (fp & rem_m),
                    _SENTINEL)
    rfp_s = jnp.sort(rfp)                      # valid first, sorted (rq, rem)
    valid_s = rfp_s != _SENTINEL
    rq_s = (rfp_s >> jnp.uint32(r)).astype(jnp.int32)
    j = jax.lax.broadcasted_iota(jnp.int32, (L,), 0)
    pos = j + jax.lax.cummax(rq_s - j)
    prev_rq = jnp.take(rq_s, jnp.mod(j - 1, L), axis=0)
    cont = valid_s & (j > 0) & (rq_s == prev_rq)
    shifted = valid_s & (pos != rq_s)
    lane = ((rfp_s & rem_m)
            | jnp.where(cont, cont_m, jnp.uint32(0))
            | jnp.where(shifted, shift_m, jnp.uint32(0)))
    tgt = jnp.where(valid_s, jnp.mod(pos + anchor + 1, n), n)
    lanes = jnp.zeros((n,), jnp.uint32).at[tgt].set(lane, mode="drop")
    occ_tgt = jnp.where(valid, q, n)
    occ_arr = jnp.zeros((n,), jnp.bool_).at[occ_tgt].set(True, mode="drop")
    return lanes | jnp.where(occ_arr, occ_m, jnp.uint32(0))


# ---------------------------------------------------------------------------
# contains — whole-tile gather + fused run scan
# ---------------------------------------------------------------------------

def quotient_contains(spec: FilterSpec, table: jnp.ndarray,
                      keys: jnp.ndarray) -> jnp.ndarray:
    """(n,) bool membership: probe remainder present in the home
    quotient's run.

    Kernel-safe whole-tile form (this exact function IS the Pallas
    contains kernel body): one metadata scan over the resident table
    (cumulative run-start and occupied counts, shared by every probe in
    the tile) identifies run #k with the k-th occupied slot; each probe
    then needs two gathers (is_occupied at its home slot, its home's
    occupied rank) and one fused compare over the slot lanes — no per-key
    cluster walk, no data-dependent loop."""
    n = spec.n_slots
    lanes = unpack_slots(spec, table)
    occ, cont, _, in_use, rem = _fields(spec, lanes)
    anchor = jnp.argmax(~in_use).astype(jnp.int32)
    occ_r = _rotated(n, anchor, occ)
    cont_r = _rotated(n, anchor, cont)
    in_use_r = _rotated(n, anchor, in_use)
    rem_r = _rotated(n, anchor, rem)
    runs_upto = jnp.cumsum((in_use_r & ~cont_r).astype(jnp.int32))
    occ_upto = jnp.cumsum(occ_r.astype(jnp.int32))

    fp = quotient_hashes(spec, keys)
    q, pr = split_fp(spec, fp)
    home_occupied = jnp.take(occ, q, axis=0)
    run_id = jnp.take(occ_upto, jnp.mod(q - anchor - 1, n), axis=0)
    hit = (in_use_r[None, :]
           & (runs_upto[None, :] == run_id[:, None])
           & (rem_r[None, :] == pr[:, None]))
    return home_occupied & jnp.any(hit, axis=1)


def quotient_contains_coop(spec: FilterSpec, table: jnp.ndarray,
                           keys: jnp.ndarray) -> jnp.ndarray:
    """Cooperative early-exit contains: the tile shares ONE home-slot
    ballot before paying for the run scan. ``home_occupied`` needs only the
    decoded occupied bits (one gather per key); the rotation, the two
    cumulative scans and the (tile × n_slots) hit matrix — the expensive
    phase — run under a ``lax.cond`` that the whole tile skips when no
    key's home quotient is occupied (every result is then False by the
    ``home_occupied &`` guard). Bit-exact with :func:`quotient_contains`
    for either branch, kernel-safe like the baseline (this function is the
    coop Pallas contains kernel body)."""
    n = spec.n_slots
    lanes = unpack_slots(spec, table)
    occ, cont, _, in_use, rem = _fields(spec, lanes)
    fp = quotient_hashes(spec, keys)
    q, pr = split_fp(spec, fp)
    home_occupied = jnp.take(occ, q, axis=0)

    def run_scan(ho):
        anchor = jnp.argmax(~in_use).astype(jnp.int32)
        occ_r = _rotated(n, anchor, occ)
        cont_r = _rotated(n, anchor, cont)
        in_use_r = _rotated(n, anchor, in_use)
        rem_r = _rotated(n, anchor, rem)
        runs_upto = jnp.cumsum((in_use_r & ~cont_r).astype(jnp.int32))
        occ_upto = jnp.cumsum(occ_r.astype(jnp.int32))
        run_id = jnp.take(occ_upto, jnp.mod(q - anchor - 1, n), axis=0)
        hit = (in_use_r[None, :]
               & (runs_upto[None, :] == run_id[:, None])
               & (rem_r[None, :] == pr[:, None]))
        return ho & jnp.any(hit, axis=1)

    return jax.lax.cond(jnp.any(home_occupied), run_scan,
                        lambda ho: jnp.zeros_like(ho), home_occupied)


# ---------------------------------------------------------------------------
# add / remove — decode + rebuild tiles (shared verbatim by the kernels)
# ---------------------------------------------------------------------------

def quotient_insert_tile(spec: FilterSpec, table: jnp.ndarray,
                         fp: jnp.ndarray, valid: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tile's bulk insert: decode the resident multiset, admit new
    fingerprints first-come-first-served up to capacity (n_slots - 1),
    rebuild the canonical layout. Returns (table words, ok per key) —
    ``ok=False`` is the explicit table-full signal; invalid (padding)
    slots are exact no-ops reported as ok=True."""
    lanes = unpack_slots(spec, table)
    tab_fp, tab_valid = _decode_rotated(spec, lanes)
    room = jnp.int32(spec.n_slots - 1) - jnp.sum(tab_valid.astype(jnp.int32))
    ok = valid & (jnp.cumsum(valid.astype(jnp.int32)) <= room)
    new_lanes = _layout(spec, jnp.concatenate([tab_fp, fp]),
                        jnp.concatenate([tab_valid, ok]))
    return pack_slots(spec, new_lanes), ok | ~valid


def quotient_remove_tile(spec: FilterSpec, table: jnp.ndarray,
                         fp: jnp.ndarray, valid: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tile's bulk delete: each key clears ONE stored copy of its
    fingerprint (duplicate requests in a batch consume one copy each, in
    batch order). Returns (table words, found per key); found=False means
    no copy was left for that request. Invalid slots are no-ops with
    found=True."""
    T = fp.shape[0]
    lanes = unpack_slots(spec, table)
    tab_fp, tab_valid = _decode_rotated(spec, lanes)
    tab_sorted = jnp.sort(jnp.where(tab_valid, tab_fp, _SENTINEL))
    bfp = jnp.where(valid, fp, _SENTINEL)
    order = jnp.argsort(bfp, stable=True)          # batch order within ties
    bs = jnp.take(bfp, order, axis=0)
    jt = jax.lax.broadcasted_iota(jnp.int32, (T,), 0)
    rank = jt - jnp.searchsorted(bs, bs, side="left").astype(jnp.int32)
    cnt_tab = (jnp.searchsorted(tab_sorted, bs, side="right")
               - jnp.searchsorted(tab_sorted, bs, side="left")
               ).astype(jnp.int32)
    found_s = (bs != _SENTINEL) & (rank < cnt_tab)
    found = jnp.zeros((T,), jnp.bool_).at[order].set(found_s)
    # per-fingerprint deletion counts: drop the first nrem copies of each
    removed = jnp.sort(jnp.where(found_s, bs, _SENTINEL))
    n = spec.n_slots
    jn = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    trank = jn - jnp.searchsorted(tab_sorted, tab_sorted,
                                  side="left").astype(jnp.int32)
    nrem = (jnp.searchsorted(removed, tab_sorted, side="right")
            - jnp.searchsorted(removed, tab_sorted, side="left")
            ).astype(jnp.int32)
    keep = (tab_sorted != _SENTINEL) & (trank >= nrem)
    return pack_slots(spec, _layout(spec, tab_sorted, keep)), found | ~valid


def _as_valid(n: int, valid: Optional[jnp.ndarray]) -> jnp.ndarray:
    if valid is None:
        return jnp.ones((n,), jnp.bool_)
    return jnp.asarray(valid).astype(jnp.bool_)


def _bulk(spec: FilterSpec, table: jnp.ndarray, keys: jnp.ndarray,
          valid, tile, tile_fn):
    assert spec.is_quotient
    n = keys.shape[0]
    if n == 0:
        return table, jnp.zeros((0,), jnp.bool_)
    fp = quotient_hashes(spec, keys)
    v = _as_valid(n, valid)
    T = tile or QUOTIENT_ADD_TILE
    flags = []
    for c in range(0, n, T):                     # trace-time chunking
        sl = slice(c, min(c + T, n))
        table, f = tile_fn(spec, table, fp[sl], v[sl])
        flags.append(f)
    return table, (flags[0] if len(flags) == 1 else jnp.concatenate(flags))


def quotient_add(spec: FilterSpec, table: jnp.ndarray, keys: jnp.ndarray,
                 valid: Optional[jnp.ndarray] = None,
                 tile: Optional[int] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bulk insert. Returns ``(table, ok)`` with ``ok[i]=False`` iff the
    table had no room left for key i (capacity n_slots - 1; admission is
    first-come-first-served in batch order) — the EXPLICIT failure signal
    the API accumulates into ``Filter.insert_failures``.

    Because the layout is a pure function of the stored multiset, the
    resulting table is bit-identical for ANY tile size — and identical to
    the Pallas kernel's build. ``valid`` masks padding (inserts are not
    idempotent: a duplicate key stores a second fingerprint copy)."""
    return _bulk(spec, table, keys, valid, tile, quotient_insert_tile)


def quotient_remove(spec: FilterSpec, table: jnp.ndarray, keys: jnp.ndarray,
                    valid: Optional[jnp.ndarray] = None,
                    tile: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bulk delete: each key removes ONE copy of its fingerprint. Returns
    ``(table, found)``; ``found[i]=False`` means key i's fingerprint was
    absent (or already consumed by an earlier duplicate in the batch).

    Only remove keys that were actually inserted — the fingerprint-filter
    contract (shared with cuckoo): deleting a never-inserted key can clear
    a colliding key's fingerprint and induce false negatives."""
    return _bulk(spec, table, keys, valid, tile, quotient_remove_tile)


# ---------------------------------------------------------------------------
# merge / resize — the lossless structural ops
# ---------------------------------------------------------------------------

def quotient_merge(spec: FilterSpec, table_a: jnp.ndarray,
                   table_b: jnp.ndarray) -> jnp.ndarray:
    """Union of two same-spec tables: decode both multisets, rebuild.

    Lossless by construction — the result is bit-identical to a table
    built from the concatenated key streams (the layout is a pure
    function of the union multiset). The caller checks capacity
    (count_a + count_b <= n_slots - 1) before invoking; overflow here
    would silently violate losslessness, so `api` refuses it eagerly."""
    fa, va = _decode_rotated(spec, unpack_slots(spec, table_a))
    fb, vb = _decode_rotated(spec, unpack_slots(spec, table_b))
    return pack_slots(spec, _layout(spec, jnp.concatenate([fa, fb]),
                                    jnp.concatenate([va, vb])))


def spec_for_resize(spec: FilterSpec, new_m_bits: int) -> FilterSpec:
    """The resized spec: same slot lane width, same fingerprint width
    p = q + r — each doubling moves one bit from remainder to quotient.
    Raises ``ValueError`` when the split leaves r outside [1, lane-3]."""
    assert spec.is_quotient
    new_slots = new_m_bits // spec.slot_bits
    _log2i(new_m_bits)
    new_q = _log2i(new_slots)
    new_r = spec.fingerprint_bits - new_q
    if not 1 <= new_r <= spec.slot_bits - QF_META_BITS:
        raise ValueError(
            f"cannot resize {spec} to m=2^{_log2i(new_m_bits)}b: the "
            f"conserved fingerprint width p={spec.fingerprint_bits} splits "
            f"as q={new_q}, r={new_r}, but r must stay in "
            f"[1, {spec.slot_bits - QF_META_BITS}] for u{spec.slot_bits} "
            f"slots")
    return dataclasses.replace(spec, m_bits=new_m_bits, r_bits=new_r)


def quotient_resize(spec: FilterSpec, table: jnp.ndarray,
                    new_spec: FilterSpec) -> jnp.ndarray:
    """Re-slot the stored fingerprints into a table of a different size.

    The p-bit fingerprint VALUES are conserved; only the q/r split moves,
    so every stored element re-homes exactly — no raw keys, no FPR drift
    beyond the analytic effect of the new split. The caller checks
    capacity for shrinks (grows can't overflow)."""
    assert spec.is_quotient and new_spec.is_quotient
    assert new_spec.fingerprint_bits == spec.fingerprint_bits, \
        "resize conserves p = q + r"
    fp, valid = _decode_rotated(spec, unpack_slots(spec, table))
    return pack_slots(new_spec, _layout(new_spec, fp, valid))


# ---------------------------------------------------------------------------
# Introspection + theory + sizing
# ---------------------------------------------------------------------------

def occupied_slots(spec: FilterSpec, table: jnp.ndarray) -> jnp.ndarray:
    """Scalar uint32: number of in-use slots == stored fingerprints
    (bank-shaped tables report per-member counts over the last axis)."""
    lanes = unpack_slots(spec, table)
    meta = (lanes >> jnp.uint32(spec.slot_bits - QF_META_BITS)) & jnp.uint32(7)
    return jnp.sum((meta != 0).astype(jnp.uint32), axis=-1)


def quotient_load_factor(spec: FilterSpec, table: jnp.ndarray) -> jnp.ndarray:
    """Occupied fraction of all slots — the fingerprint filter's fill
    metric (bit-density ``fill_fraction`` is meaningless for slot values)."""
    return occupied_slots(spec, table).astype(jnp.float32) / spec.n_slots


def fpr_quotient(q_bits: int, r_bits: int, alpha: float) -> float:
    """Analytic FPR at load ``alpha``: a negative probe false-positives
    iff its full p = q + r bit fingerprint collides with any of the
    ``alpha * 2^q`` stored ones — exactly ``1 - (1 - 2^-p)^n ~= alpha *
    2^-r`` (exact fingerprint compare, no per-slot probe union like
    cuckoo's 2*b candidate slots)."""
    n = alpha * (2.0 ** q_bits)
    return 1.0 - (1.0 - 2.0 ** -(q_bits + r_bits)) ** n


def bits_per_key(spec: FilterSpec, n: Optional[int] = None) -> float:
    """Storage bits per stored key (at load n; default: max load)."""
    n = n or max(int(spec.n_slots * QUOTIENT_MAX_LOAD), 1)
    return spec.m_bits / max(n, 1)


def r_bits_for_fpr(target_fpr: float, q_bits: int,
                   alpha: float = QUOTIENT_MAX_LOAD) -> int:
    """Smallest remainder width meeting ``target_fpr`` at load ``alpha``."""
    r = max(int(math.ceil(math.log2(max(alpha, 1e-9) / target_fpr))), 1)
    while fpr_quotient(q_bits, r, alpha) > target_fpr and r < 29:
        r += 1
    return r


def spec_for_n(n: int, target_fpr: Optional[float] = None,
               slot_bits: Optional[int] = None,
               max_load: float = QUOTIENT_MAX_LOAD) -> FilterSpec:
    """Size a quotient spec for ~n keys at load factor <= ``max_load``.

    The slot count rounds up to a power of two (so realized load is at
    most ``max_load``); the remainder width comes from the target FPR at
    the realized load, and the slot lane snaps to the smallest of
    u8/u16/u32 that fits r + 3 metadata bits."""
    q = max(int(math.ceil(math.log2(max(n, 1) / max_load))), 3)
    while (1 << q) - 1 < n:
        q += 1
    alpha = n / float(1 << q)
    if target_fpr is None:
        r = (slot_bits - QF_META_BITS) if slot_bits else 5
    else:
        r = r_bits_for_fpr(target_fpr, q, max(alpha, 1e-9))
    if slot_bits is None:
        for sb in QUOTIENT_SLOT_BITS:
            if r <= sb - QF_META_BITS:
                slot_bits = sb
                break
        else:
            raise ValueError(
                f"no supported quotient slot width holds r={r} remainder "
                f"bits (+{QF_META_BITS} metadata); relax target_fpr "
                f"{target_fpr!r}")
    elif r > slot_bits - QF_META_BITS:
        raise ValueError(
            f"u{slot_bits} slots hold at most {slot_bits - QF_META_BITS} "
            f"remainder bits; fpr {target_fpr!r} at load {max_load} "
            f"needs r={r}")
    if q + r > 31:
        raise ValueError(
            f"fingerprint q+r = {q}+{r} exceeds the uint32 budget (31 "
            f"bits); shard the keyspace or relax target_fpr")
    return FilterSpec(variant="quotient", m_bits=(1 << q) * slot_bits, k=1,
                      slot_bits=slot_bits, r_bits=r)
