"""Bucketed cuckoo fingerprint filter — pure-jnp reference semantics.

The fingerprint AMQ family (Fan et al.'s cuckoo filter) the GPU filter
literature benchmarks Bloom designs against ("High-Performance Filters for
GPUs", "Cuckoo-GPU"), adapted to the repo's conventions:

* the table is a flat ``(n_words,)`` uint32 array — ``n_buckets`` buckets of
  ``slots_per_bucket`` fingerprints, ``slot_bits`` (8 or 16) each, packed
  little-endian into ``s = bucket_bits/32`` words per bucket. A bucket is
  the "block" of the shared :class:`FilterSpec` geometry, so VMEM budgets,
  bank offsets and row gathers reuse the Bloom machinery unchanged;
* **partial-key hashing**: the block hash stream picks the primary bucket,
  the pattern stream yields the fingerprint (forced nonzero — 0 means
  empty slot); the alternate bucket is ``b XOR h(fp)``, an involution, so
  relocation during kicks never needs the original key;
* **bounded-kick eviction** under ``lax.while_loop``: an insert that finds
  both candidate buckets full evicts a deterministic pseudo-random victim
  and relocates it, up to :data:`CUCKOO_MAX_KICKS` hops. The loop bound
  makes the op jit/scan-compilable; exceeding it returns an EXPLICIT
  failure flag per key (``ok=False``) — never a silent drop. Failed
  inserts leave a relocated-but-consistent table (the standard cuckoo
  behavior: the displaced fingerprint chain remains findable);
* inserts and removes are **not idempotent** (a duplicate key occupies a
  second slot; a remove clears exactly one matching slot), so bulk ops
  take a ``valid`` mask for padding — never repeat-key padding;
* bulk-add order is DETERMINISTIC and tile-stable: keys are processed in
  :data:`CUCKOO_ADD_TILE` chunks, each chunk stably sorted by primary
  bucket ("block-sorted", coalescing same-bucket RMWs) — exactly the
  schedule of the Pallas kernel (`kernels.cuckoofilter`), which is what
  makes jnp-vs-Pallas builds bit-identical.

Every function here is plain jnp/lax vector code, so the same helpers run
inside Pallas kernel bodies (interpret or compiled) and under
vmap/jit/scan — the single source of truth the kernels validate against.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hashing as H
from repro.core.variants import CUCKOO_SLOT_BITS, FilterSpec, _log2i

CUCKOO_MAX_KICKS = 64          # bounded eviction chain per insert
CUCKOO_ADD_TILE = 2048         # bulk-add chunk (sort + insert unit)
CUCKOO_MAX_LOAD = 0.95         # standard achievable load, 4-slot buckets

# fingerprint-stream salt (index 0) and alternate-bucket salt (index 1):
# distinct fixed members of the global salt table, inlined at trace time
_FP_SALT = H.SALTS[0]
_ALT_SALT = H.SALTS[1]

_LCG_MUL = np.uint32(747796405)       # PCG-style victim-slot stream
_LCG_ADD = np.uint32(2891336453)


def init(spec: FilterSpec) -> jnp.ndarray:
    assert spec.is_fingerprint
    return jnp.zeros((spec.n_words,), jnp.uint32)


# ---------------------------------------------------------------------------
# Hashing: partial-key scheme
# ---------------------------------------------------------------------------

def cuckoo_hashes(spec: FilterSpec, keys: jnp.ndarray):
    """(primary bucket (n,) int32, fingerprint (n,) uint32 in [1, 2^f),
    rng seed (n,) uint32 for the kick-path victim stream).

    The fingerprint comes from the pattern hash stream, the bucket from the
    block stream — same split as the Bloom kernels' phase 1. ``fp == 0``
    is remapped to 1 (0 encodes an empty slot)."""
    h1 = H.xxh32_u64x2(keys, H.SEED_PATTERN)
    h2 = H.xxh32_u64x2(keys, H.SEED_BLOCK)
    fp = H.mulshift(h1, _FP_SALT, spec.slot_bits)
    fp = jnp.where(fp == 0, jnp.uint32(1), fp)
    b1 = H.block_index(h2, spec.n_buckets).astype(jnp.int32)
    rng = h1 ^ H.SEED_AUX
    return b1, fp, rng


def alt_bucket(spec: FilterSpec, b: jnp.ndarray, fp: jnp.ndarray):
    """The XOR-derived alternate bucket: ``alt(alt(b, fp), fp) == b``.

    Works on scalars (kernel kick loop) and vectors (bulk contains)."""
    lg = _log2i(spec.n_buckets)
    if lg == 0:
        return b
    h = H.mulshift(fp, _ALT_SALT, lg).astype(jnp.int32)
    return b ^ h


# ---------------------------------------------------------------------------
# Slot packing: u8/u16 fingerprints in u32 words
# ---------------------------------------------------------------------------

def unpack_slots(spec: FilterSpec, words: jnp.ndarray) -> jnp.ndarray:
    """(..., s) bucket words -> (..., slots_per_bucket) fingerprints.
    Slot j lives in word ``j // slots_per_word``, lane ``j % slots_per_word``
    (little-endian). The loop unrolls at trace time."""
    sb, spw = spec.slot_bits, spec.slots_per_word
    mask = jnp.uint32((1 << sb) - 1)
    lanes = [(words[..., j // spw] >> jnp.uint32(sb * (j % spw))) & mask
             for j in range(spec.slots_per_bucket)]
    return jnp.stack(lanes, axis=-1)


def pack_slots(spec: FilterSpec, slots: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`unpack_slots`: (..., spb) -> (..., s) words."""
    sb, spw = spec.slot_bits, spec.slots_per_word
    words = []
    for w in range(spec.s):
        acc = jnp.zeros(slots.shape[:-1], jnp.uint32)
        for lane in range(spw):
            acc = acc | (slots[..., w * spw + lane] << jnp.uint32(sb * lane))
        words.append(acc)
    return jnp.stack(words, axis=-1)


# ---------------------------------------------------------------------------
# contains — whole-batch gather + fused two-bucket compare
# ---------------------------------------------------------------------------

def cuckoo_contains(spec: FilterSpec, table: jnp.ndarray, keys: jnp.ndarray
                    ) -> jnp.ndarray:
    """(n,) bool membership: fingerprint present in either candidate bucket.

    One flat-index gather per candidate bucket over the whole batch and a
    single fused compare — written in the kernel-safe idiom
    (broadcasted_iota + take on the flat word array), so this exact
    function IS the Pallas contains kernel body."""
    n, s = keys.shape[0], spec.s
    b1, fp, _ = cuckoo_hashes(spec, keys)
    b2 = alt_bucket(spec, b1, fp)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, s), 1)
    w1 = jnp.take(table, b1[:, None] * s + col, axis=0)       # (n, s)
    w2 = jnp.take(table, b2[:, None] * s + col, axis=0)
    s1 = unpack_slots(spec, w1)                               # (n, spb)
    s2 = unpack_slots(spec, w2)
    return (jnp.any(s1 == fp[:, None], axis=-1)
            | jnp.any(s2 == fp[:, None], axis=-1))


def cuckoo_contains_coop(spec: FilterSpec, table: jnp.ndarray,
                         keys: jnp.ndarray) -> jnp.ndarray:
    """Cooperative early-exit contains: the tile probes all PRIMARY buckets
    together first, and only gathers the alternate buckets when some key is
    still unresolved (the cooperative ballot, ``lax.cond`` on the whole
    tile). At realistic loads most present keys sit in their primary
    bucket, so the second gather — half the memory traffic — is frequently
    skipped for the whole tile. Bit-exact with :func:`cuckoo_contains`: the
    result is the same OR of the two bucket tests, and a key already hit in
    its primary bucket stays a hit whether or not phase 2 runs."""
    n, s = keys.shape[0], spec.s
    b1, fp, _ = cuckoo_hashes(spec, keys)
    b2 = alt_bucket(spec, b1, fp)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, s), 1)
    w1 = jnp.take(table, b1[:, None] * s + col, axis=0)       # (n, s)
    hit1 = jnp.any(unpack_slots(spec, w1) == fp[:, None], axis=-1)

    def probe_alt(h):
        w2 = jnp.take(table, b2[:, None] * s + col, axis=0)
        return h | jnp.any(unpack_slots(spec, w2) == fp[:, None], axis=-1)

    return jax.lax.cond(jnp.all(hit1), lambda h: h, probe_alt, hit1)


# ---------------------------------------------------------------------------
# add — block-sorted tiles, bounded-kick eviction, explicit failure signal
# ---------------------------------------------------------------------------

def _bucket_words(spec: FilterSpec, table: jnp.ndarray, b) -> jnp.ndarray:
    return jax.lax.dynamic_slice(table, (b * spec.s,), (spec.s,))


def _store_bucket(spec: FilterSpec, table: jnp.ndarray, b,
                  slots: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(table, pack_slots(spec, slots),
                                        (b * spec.s,))


def _try_place(spec: FilterSpec, table: jnp.ndarray, b, fp):
    """Place ``fp`` in the first free slot of bucket ``b`` if any.
    Returns (table, placed: bool). Branch-free: a full bucket writes its
    own contents back (no-op)."""
    slots = unpack_slots(spec, _bucket_words(spec, table, b))   # (spb,)
    free = slots == 0
    placed = jnp.any(free)
    idx = jnp.argmax(free)
    lane = jnp.arange(spec.slots_per_bucket)
    new = jnp.where((lane == idx) & placed, fp, slots)
    return _store_bucket(spec, table, b, new), placed


def _insert_one(spec: FilterSpec, table: jnp.ndarray, b1, fp, rng, valid):
    """One key's insert: try both candidate buckets, then the bounded kick
    chain. Returns (table, ok). Invalid (padding) slots are exact no-ops
    reported as ok=True (nothing was dropped — nothing was asked)."""
    spb = spec.slots_per_bucket
    lg_spb = _log2i(spb)
    lane = jnp.arange(spb)

    def run(tbl):
        t, placed = _try_place(spec, tbl, b1, fp)
        b2 = alt_bucket(spec, b1, fp)
        t, placed = jax.lax.cond(
            placed, lambda a: (a, jnp.bool_(True)),
            lambda a: _try_place(spec, a, b2, fp), t)

        def kick_cond(st):
            _, _, _, _, kicks, placed = st
            return (~placed) & (kicks < CUCKOO_MAX_KICKS)

        def kick_body(st):
            t, b, f, r, kicks, _ = st
            # evict a pseudo-random victim from the full bucket b ...
            slots = unpack_slots(spec, _bucket_words(spec, t, b))
            if lg_spb == 0:
                v = jnp.int32(0)
            else:
                v = (r >> jnp.uint32(32 - lg_spb)).astype(jnp.int32)
            victim = jax.lax.dynamic_index_in_dim(slots, v, keepdims=False)
            t = _store_bucket(spec, t, b, jnp.where(lane == v, f, slots))
            # ... and relocate it to ITS alternate bucket (XOR involution:
            # derived from the victim fingerprint alone, no key needed)
            f = victim
            b = alt_bucket(spec, b, f)
            t, placed = _try_place(spec, t, b, f)
            return (t, b, f, r * _LCG_MUL + _LCG_ADD, kicks + 1, placed)

        t, _, _, _, _, placed = jax.lax.while_loop(
            kick_cond, kick_body,
            (t, b2, fp, rng, jnp.int32(0), placed))
        return t, placed

    return jax.lax.cond(valid, run, lambda tbl: (tbl, jnp.bool_(True)),
                        table)


def _tile_loop(spec: FilterSpec, table: jnp.ndarray, b1, fp, rng, valid,
               one_fn):
    """Stable-sort one tile by primary bucket, then apply ``one_fn``
    sequentially in sorted order; flags are returned in ORIGINAL order.

    The sort is the "block-sorted partition" of the bulk build: same-bucket
    keys become adjacent runs whose RMWs coalesce, and — because the whole
    tile is applied by one sequential owner — kicks crossing partition
    boundaries need no atomics (DESIGN.md §13)."""
    n = b1.shape[0]
    order = jnp.argsort(b1)                      # stable
    inv = jnp.argsort(order)
    sb1, sfp = b1[order], fp[order]
    srng, sval = rng[order], valid[order]

    def body(i, carry):
        tbl, ok = carry
        tbl, oki = one_fn(spec, tbl,
                          jax.lax.dynamic_index_in_dim(sb1, i, keepdims=False),
                          jax.lax.dynamic_index_in_dim(sfp, i, keepdims=False),
                          jax.lax.dynamic_index_in_dim(srng, i, keepdims=False),
                          jax.lax.dynamic_index_in_dim(sval, i, keepdims=False))
        return tbl, jax.lax.dynamic_update_slice(ok, oki[None], (i,))

    table, ok_sorted = jax.lax.fori_loop(
        0, n, body, (table, jnp.zeros((n,), jnp.bool_)))
    return table, ok_sorted[inv]


def cuckoo_insert_tile(spec: FilterSpec, table: jnp.ndarray, b1, fp, rng,
                       valid) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tile's bulk insert (shared verbatim by the Pallas add kernel)."""
    return _tile_loop(spec, table, b1, fp, rng, valid, _insert_one)


def _as_valid(n: int, valid: Optional[jnp.ndarray]) -> jnp.ndarray:
    if valid is None:
        return jnp.ones((n,), jnp.bool_)
    return jnp.asarray(valid).astype(jnp.bool_)


def cuckoo_add(spec: FilterSpec, table: jnp.ndarray, keys: jnp.ndarray,
               valid: Optional[jnp.ndarray] = None,
               tile: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bulk insert. Returns ``(table, ok)`` with ``ok[i]=False`` iff key i's
    kick chain exceeded :data:`CUCKOO_MAX_KICKS` — the EXPLICIT
    insert-failure signal (surface it; the table is over capacity).

    Failure accounting is exact — each failure leaves exactly one
    fingerprint homeless, so ``occupied_slots == sum(ok)`` always — but,
    as in every cuckoo filter, the homeless fingerprint is the LAST
    victim of the kick chain, which may belong to an earlier key rather
    than the failing one. A nonzero failure count therefore means
    "resize/rebuild": the no-false-negative guarantee holds only for
    tables that never reported a failure.

    ``valid`` masks padding slots (inserts are not idempotent).
    ``tile`` pins the chunk size (default :data:`CUCKOO_ADD_TILE`) — the
    chunk boundaries and in-chunk bucket sort define the deterministic
    insertion order the Pallas kernel reproduces bit-for-bit."""
    assert spec.is_fingerprint
    n = keys.shape[0]
    if n == 0:
        return table, jnp.zeros((0,), jnp.bool_)
    b1, fp, rng = cuckoo_hashes(spec, keys)
    v = _as_valid(n, valid)
    T = tile or CUCKOO_ADD_TILE
    oks = []
    for c in range(0, n, T):                     # trace-time chunking
        sl = slice(c, min(c + T, n))
        table, ok = cuckoo_insert_tile(spec, table, b1[sl], fp[sl],
                                       rng[sl], v[sl])
        oks.append(ok)
    return table, (oks[0] if len(oks) == 1 else jnp.concatenate(oks))


# ---------------------------------------------------------------------------
# remove — clear exactly one matching slot per key
# ---------------------------------------------------------------------------

def _remove_one(spec: FilterSpec, table: jnp.ndarray, b1, fp, rng, valid):
    """Clear the first slot matching ``fp`` in the primary bucket, else in
    the alternate. Returns (table, found). Removing an absent key is a
    guarded no-op with found=False (never corrupts other keys)."""
    lane = jnp.arange(spec.slots_per_bucket)

    def clear(tbl, b):
        slots = unpack_slots(spec, _bucket_words(spec, tbl, b))
        hit = slots == fp
        found = jnp.any(hit)
        idx = jnp.argmax(hit)
        new = jnp.where((lane == idx) & found, jnp.uint32(0), slots)
        return _store_bucket(spec, tbl, b, new), found

    def run(tbl):
        t, found = clear(tbl, b1)
        return jax.lax.cond(
            found, lambda a: (a, jnp.bool_(True)),
            lambda a: clear(a, alt_bucket(spec, b1, fp)), t)

    return jax.lax.cond(valid, run, lambda tbl: (tbl, jnp.bool_(True)),
                        table)


def cuckoo_remove_tile(spec: FilterSpec, table: jnp.ndarray, b1, fp, rng,
                       valid) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tile's bulk remove (shared verbatim by the Pallas kernel)."""
    return _tile_loop(spec, table, b1, fp, rng, valid, _remove_one)


def cuckoo_remove(spec: FilterSpec, table: jnp.ndarray, keys: jnp.ndarray,
                  valid: Optional[jnp.ndarray] = None,
                  tile: Optional[int] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bulk delete: each key clears ONE slot holding its fingerprint
    (duplicates in the batch clear one slot each — same-bucket order is
    the sorted sequential order, identical in jnp and Pallas). Returns
    ``(table, found)``; ``found[i]=False`` means key i was not present
    (or its fingerprint was already cleared by an earlier duplicate).

    Only remove keys that were actually inserted — the cuckoo contract
    (shared with every fingerprint filter): deleting a never-inserted key
    can clear a colliding key's fingerprint and induce false negatives."""
    assert spec.is_fingerprint
    n = keys.shape[0]
    if n == 0:
        return table, jnp.zeros((0,), jnp.bool_)
    b1, fp, rng = cuckoo_hashes(spec, keys)
    v = _as_valid(n, valid)
    T = tile or CUCKOO_ADD_TILE
    outs = []
    for c in range(0, n, T):
        sl = slice(c, min(c + T, n))
        table, found = cuckoo_remove_tile(spec, table, b1[sl], fp[sl],
                                          rng[sl], v[sl])
        outs.append(found)
    return table, (outs[0] if len(outs) == 1 else jnp.concatenate(outs))


# ---------------------------------------------------------------------------
# Introspection + theory + sizing
# ---------------------------------------------------------------------------

def occupied_slots(spec: FilterSpec, table: jnp.ndarray) -> jnp.ndarray:
    """Scalar uint32: number of nonzero fingerprint slots (bank-shaped
    tables report per-member counts over the last axis)."""
    slots = unpack_slots(spec, table.reshape(*table.shape[:-1],
                                             spec.n_buckets, spec.s))
    return jnp.sum((slots != 0).astype(jnp.uint32), axis=(-1, -2))


def cuckoo_load_factor(spec: FilterSpec, table: jnp.ndarray) -> jnp.ndarray:
    """Occupied fraction of all slots — the fingerprint filter's fill
    metric (bit-density ``fill_fraction`` is meaningless for slot values)."""
    return occupied_slots(spec, table).astype(jnp.float32) / spec.n_slots


def fpr_cuckoo(slot_bits: int, slots_per_bucket: int, alpha: float) -> float:
    """Analytic FPR at load factor ``alpha``: a negative probe scans
    ``2*slots_per_bucket`` slots, each occupied w.p. alpha, each occupied
    slot matching w.p. ``(2^f + 2) / 4^f`` (the exact collision mass of
    the nonzero-forced fingerprint map, ~= 2^-f)."""
    two_f = 2.0 ** slot_bits
    p_match = (two_f + 2.0) / (two_f * two_f)
    return 1.0 - (1.0 - p_match) ** (2.0 * slots_per_bucket * alpha)


def bits_per_key(spec: FilterSpec, n: Optional[int] = None) -> float:
    """Storage bits per stored key (at load n; default: max load)."""
    n = n or int(spec.n_slots * CUCKOO_MAX_LOAD)
    return spec.m_bits / max(n, 1)


def slot_bits_for_fpr(target_fpr: float, slots_per_bucket: int = 4,
                      max_load: float = CUCKOO_MAX_LOAD) -> Optional[int]:
    """Smallest supported slot width meeting ``target_fpr`` at max load
    (None if even u16 fingerprints cannot)."""
    for f in CUCKOO_SLOT_BITS:
        if fpr_cuckoo(f, slots_per_bucket, max_load) <= target_fpr:
            return f
    return None


def spec_for_n(n: int, target_fpr: Optional[float] = None,
               slot_bits: Optional[int] = None, slots_per_bucket: int = 4,
               max_load: float = CUCKOO_MAX_LOAD) -> FilterSpec:
    """Size a cuckoo spec for ~n keys at load factor <= ``max_load``.

    ``slot_bits`` defaults to the smallest width meeting ``target_fpr``
    (or u8 when no target is given). Bucket count rounds up to a power of
    two, so the realized load is at most ``max_load``."""
    if slot_bits is None:
        if target_fpr is None:
            slot_bits = 8
        else:
            slot_bits = slot_bits_for_fpr(target_fpr, slots_per_bucket,
                                          max_load)
            if slot_bits is None:
                raise ValueError(
                    f"no supported cuckoo slot width reaches fpr "
                    f"{target_fpr:g} at load {max_load}; use a Bloom "
                    f"variant or lower the load")
    need = max(int(math.ceil(n / (max_load * slots_per_bucket))), 1)
    n_buckets = 1 << max(int(math.ceil(math.log2(need))), 0)
    m_bits = n_buckets * slots_per_bucket * slot_bits
    return FilterSpec(variant="cuckoo", m_bits=m_bits, k=2,
                      slot_bits=slot_bits, slots_per_bucket=slots_per_bucket)
