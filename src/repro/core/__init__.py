"""Core: the paper's contribution — TPU-native Bloom filter substrate."""
from repro.core.variants import (FilterSpec, VARIANTS, WORD_BITS, init, add,
                                 add_loop, add_scatter, contains,
                                 counting_add, counting_contains,
                                 counting_decay, counting_remove,
                                 fill_fraction, fpr_theory, fpr_cbf, fpr_bbf,
                                 fpr_sbf, fpr_csbf, optimal_k, fpr_min,
                                 space_optimal_n)
from repro.core import hashing
