"""User-facing Bloom filter facade.

``BloomFilter`` wraps a ``FilterSpec`` + the uint32 word array and dispatches
bulk operations to the best available execution path:

* ``backend="jnp"``    — the vectorized pure-jnp reference (CPU-friendly);
* ``backend="pallas"`` — the TPU Pallas kernels (``repro.kernels``), run in
  interpret mode off-TPU; layout (Θ, Φ) selectable / autotuned;
* ``backend="auto"``   — pallas when the spec is kernel-compatible, else jnp.

The object is immutable-functional under the hood (JAX arrays), but exposes a
mutating convenience API because that is what data-pipeline call sites want.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import variants as V
from repro.core.variants import FilterSpec


@functools.lru_cache(maxsize=256)
def _jit_contains(spec: FilterSpec):
    return jax.jit(lambda f, k: V.contains_rows(spec, f, k))


@functools.lru_cache(maxsize=256)
def _jit_add(spec: FilterSpec):
    return jax.jit(lambda f, k: V.add_rows(spec, f, k))


def _as_keys(keys) -> jnp.ndarray:
    """Accept u64x2 uint32 (n,2), np.uint64 (n,), or uint32 (n,)."""
    if isinstance(keys, np.ndarray) and keys.dtype == np.uint64:
        from repro.core.hashing import u64x2_from_u64
        keys = u64x2_from_u64(keys)
    keys = jnp.asarray(keys)
    if keys.dtype != jnp.uint32:
        keys = keys.astype(jnp.uint32)
    return keys


@dataclasses.dataclass
class BloomFilter:
    spec: FilterSpec
    words: jnp.ndarray
    backend: str = "auto"
    layout: Optional[object] = None   # kernels.sbf.Layout for the pallas path

    # -- construction -------------------------------------------------------
    @classmethod
    def create(cls, variant: str = "sbf", m_bits: int = 1 << 20, k: int = 8,
               block_bits: int = 256, z: int = 1, backend: str = "auto",
               layout=None) -> "BloomFilter":
        spec = FilterSpec(variant=variant, m_bits=m_bits, k=k,
                          block_bits=block_bits, z=z)
        return cls(spec=spec, words=V.init(spec), backend=backend, layout=layout)

    @classmethod
    def for_n_items(cls, n: int, bits_per_key: float = 16.0,
                    variant: str = "sbf", block_bits: int = 256,
                    k: Optional[int] = None, **kw) -> "BloomFilter":
        """Size a filter for ~n items at c = bits_per_key (m rounded to pow2)."""
        m = 1 << max(int(np.ceil(np.log2(max(n, 1) * bits_per_key))), 10)
        if k is None:
            k = max(int(round(V.optimal_k(m / max(n, 1)))), 1)
            if variant == "csbf":
                z = kw.get("z", 1)
                k = max(z, (k // z) * z)
            if variant == "sbf":
                s = block_bits // V.WORD_BITS
                k = max(s, (k // s) * s) if k >= s else k
            k = min(k, 32)
        return cls.create(variant=variant, m_bits=m, k=k,
                          block_bits=block_bits, **kw)

    # -- dispatch -------------------------------------------------------------
    def _use_pallas(self) -> bool:
        if self.backend == "jnp":
            return False
        from repro.kernels import ops
        ok = ops.kernel_supported(self.spec)
        if self.backend == "pallas" and not ok:
            raise ValueError(f"no pallas kernel for {self.spec}")
        if self.backend == "auto":
            # interpret-mode kernels are for validation, not speed: off-TPU
            # the vectorized jnp engine is the fast path.
            return ok and jax.default_backend() == "tpu"
        return ok

    def add(self, keys) -> "BloomFilter":
        keys = _as_keys(keys)
        if keys.shape[0] == 0:
            return self
        if self._use_pallas():
            from repro.kernels import ops
            self.words = ops.bloom_add(self.spec, self.words, keys,
                                       layout=self.layout)
        else:
            self.words = _jit_add(self.spec)(self.words, keys)
        return self

    def contains(self, keys) -> jnp.ndarray:
        keys = _as_keys(keys)
        if keys.shape[0] == 0:
            return jnp.zeros((0,), jnp.bool_)
        if self._use_pallas():
            from repro.kernels import ops
            return ops.bloom_contains(self.spec, self.words, keys,
                                      layout=self.layout)
        return _jit_contains(self.spec)(self.words, keys)

    # -- introspection --------------------------------------------------------
    def fill_fraction(self) -> float:
        return float(V.fill_fraction(self.words))

    def fpr_theory(self, n: int) -> float:
        return V.fpr_theory(self.spec, n)

    def measure_fpr(self, n_inserted: int, n_probe: int = 1 << 16,
                    seed: int = 1234) -> float:
        """Empirical FPR: probe keys disjoint from any realistic insert set."""
        from repro.core.hashing import random_u64x2
        probes = random_u64x2(n_probe, seed=seed)
        hits = np.asarray(self.contains(probes))
        return float(hits.mean())

    @property
    def nbytes(self) -> int:
        return self.spec.m_bits // 8

    def __repr__(self):
        return f"BloomFilter({self.spec}, fill={self.fill_fraction():.3f}, backend={self.backend})"
