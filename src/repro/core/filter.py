"""Deprecated mutable facade over :class:`repro.api.Filter` (one release).

``BloomFilter`` predates the pytree-native API: it exposed mutating
``add``/``contains`` and ad-hoc ``backend=`` dispatch. It now delegates
every operation to a :class:`repro.api.Filter` held internally, so the two
surfaces are bit-identical; new code should use ``repro.api`` directly:

    bf = BloomFilter.for_n_items(n, 16)      ->  api.filter_for_n_items(n, 16)
    bf.add(keys); bf.contains(keys)          ->  f = f.add(keys); f.contains(keys)
    backend="pallas"                         ->  backend="pallas-vmem" / "pallas-hbm"
                                                 (or keep "pallas": registry alias)
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax.numpy as jnp

from repro.core import variants as V
from repro.core.variants import FilterSpec


def _as_keys(keys):
    from repro.api.filter import as_keys
    return as_keys(keys)


def _warn():
    warnings.warn(
        "BloomFilter is deprecated; use repro.api.make_filter / "
        "filter_for_n_items (immutable pytree Filter, same engines).",
        DeprecationWarning, stacklevel=3)


class BloomFilter:
    """Deprecated. A thin mutable wrapper around ``repro.api.Filter``."""

    def __init__(self, spec: FilterSpec, words: jnp.ndarray,
                 backend: str = "auto", layout: Optional[object] = None):
        from repro import api
        eng = api.registry.select(spec, backend,
                                  api.BackendOptions(layout=layout).ctx())
        self._f = api.Filter(spec=spec, words=words, backend=eng.name,
                             options=api.BackendOptions(layout=layout))

    # -- construction -------------------------------------------------------
    @classmethod
    def create(cls, variant: str = "sbf", m_bits: int = 1 << 20, k: int = 8,
               block_bits: int = 256, z: int = 1, backend: str = "auto",
               layout=None) -> "BloomFilter":
        _warn()
        spec = FilterSpec(variant=variant, m_bits=m_bits, k=k,
                          block_bits=block_bits, z=z)
        return cls(spec=spec, words=V.init(spec), backend=backend,
                   layout=layout)

    @classmethod
    def for_n_items(cls, n: int, bits_per_key: float = 16.0,
                    variant: str = "sbf", block_bits: int = 256,
                    k: Optional[int] = None, backend: str = "auto",
                    layout=None, **kw) -> "BloomFilter":
        """Size a filter for ~n items at c = bits_per_key (m rounded to pow2)."""
        _warn()
        from repro import api
        f = api.filter_for_n_items(n, bits_per_key, variant=variant,
                                   block_bits=block_bits, k=k,
                                   backend=backend, layout=layout, **kw)
        obj = cls.__new__(cls)
        obj._f = f
        return obj

    # -- pass-throughs -------------------------------------------------------
    @property
    def spec(self) -> FilterSpec:
        return self._f.spec

    @property
    def words(self) -> jnp.ndarray:
        return self._f.words

    @words.setter
    def words(self, w):
        self._f = self._f.replace(words=w)

    @property
    def backend(self) -> str:
        return self._f.backend

    @property
    def layout(self):
        return self._f.options.layout

    def add(self, keys) -> "BloomFilter":
        self._f = self._f.add(keys)
        return self

    def contains(self, keys) -> jnp.ndarray:
        return self._f.contains(keys)

    # -- introspection --------------------------------------------------------
    def fill_fraction(self) -> float:
        return self._f.fill_fraction()

    def fpr_theory(self, n: int) -> float:
        return self._f.fpr_theory(n)

    def measure_fpr(self, n_inserted: int = 0, n_probe: int = 1 << 16,
                    seed: int = 1234) -> float:
        """Empirical FPR; probes come from the reserved keyspace
        (``hashing.probe_u64x2``), disjoint from every ``random_u64x2``
        insert set. ``n_inserted`` is kept for signature compatibility."""
        return self._f.measure_fpr(n_probe=n_probe, seed=seed)

    @property
    def nbytes(self) -> int:
        return self._f.nbytes

    def __repr__(self):
        return (f"BloomFilter({self.spec}, fill={self.fill_fraction():.3f}, "
                f"backend={self.backend})")
