"""Distributed Bloom filters over a JAX device mesh.

Two deployment shapes, both built on ``shard_map``:

**replicated**
    Every device holds the full word array; adds are applied locally to the
    device's replica against its own key shard, and a **butterfly OR
    all-reduce** built from ``lax.ppermute`` merges the replicas (bitwise OR
    is not a native JAX collective — log2(n) rounds, each moving m bits,
    same volume schedule as a bidirectional-ring all-reduce for small device
    counts). Between syncs the filter is eventually-consistent: a duplicate
    may slip through, the FPR is unaffected — the right trade for
    data-pipeline dedup where a missed duplicate costs one wasted sample,
    not correctness.

**sharded**
    The word array is split into per-device **segments** (contiguous block
    ranges — the distributed extension of the ownership model in
    core.partition). Bulk ops route each key to its segment owner with a
    fixed-capacity ``all_to_all`` (GShard-style: static capacity + validity
    mask), the owner runs the single-core op on its VMEM-resident segment,
    and lookup results ride the inverse all_to_all home. Capacity overflow
    degrades *conservatively*: an overflowed lookup reports "present" (an
    allowed false positive — never a false negative) and an overflowed add
    is dropped (a missed dedup, not a correctness bug).

This module holds the **pure collective transforms** (``replicated_*`` /
``sharded_*`` functions), consumed by the ``"replicated"`` / ``"sharded"``
engines in ``repro.api.registry`` — the supported surface, conforming to
the uniform ``Filter`` protocol. (The one-release ``ReplicatedFilter`` /
``ShardedFilter`` shims have been removed; use
``repro.api.make_filter(..., backend=..., mesh=...)``.)

Scale note (1000+ nodes): the sharded shape keeps per-device memory at m/n
and turns the paper's DRAM-random-access bound into a VMEM-resident-segment
workload — the multi-device generalization of the paper's cache-resident
fast path.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hashing as H
from repro.core import variants as V
from repro.core.variants import FilterSpec, WORD_BITS


# ---------------------------------------------------------------------------
# Butterfly OR all-reduce (custom collective)
# ---------------------------------------------------------------------------

def or_allreduce(x: jnp.ndarray, axis_name: str, method: str = "butterfly"
                 ) -> jnp.ndarray:
    """Bitwise-OR all-reduce along a mesh axis (inside shard_map).

    butterfly: log2(n) ppermute rounds (n must be a power of two).
    gather:    all_gather + local OR fold (any n; more memory).
    """
    # psum of a literal folds to the static axis size (works across jax
    # versions; jax.lax.axis_size only exists in newer releases)
    n = int(jax.lax.psum(1, axis_name))
    if method == "gather" or (n & (n - 1)) != 0:
        g = jax.lax.all_gather(x, axis_name, axis=0)         # (n, ...)
        acc = g[0]
        for i in range(1, n):                                 # static fold
            acc = acc | g[i]
        return acc
    step = 1
    while step < n:
        perm = [(i, i ^ step) for i in range(n)]
        x = x | jax.lax.ppermute(x, axis_name, perm)
        step <<= 1
    return x


# ---------------------------------------------------------------------------
# Localized single-device ops on a filter *segment*
# ---------------------------------------------------------------------------

def _local_fingerprints(spec: FilterSpec, keys: jnp.ndarray, blocks_per_seg: int):
    """(local word starts, masks) for keys known to belong to this segment."""
    h1 = H.xxh32_u64x2(keys, H.SEED_PATTERN)
    h2 = H.xxh32_u64x2(keys, H.SEED_BLOCK)
    blk = H.block_index(h2, spec.n_blocks)
    blk_local = blk & jnp.uint32(blocks_per_seg - 1)
    masks = V.block_patterns(spec, h1)
    starts = (blk_local * jnp.uint32(spec.s)).astype(jnp.int32)
    return starts, masks


def _segment_contains(spec: FilterSpec, seg_words: jnp.ndarray,
                      keys: jnp.ndarray, blocks_per_seg: int) -> jnp.ndarray:
    starts, masks = _local_fingerprints(spec, keys, blocks_per_seg)
    idx = starts[:, None] + jnp.arange(spec.s, dtype=jnp.int32)[None, :]
    words = seg_words[idx]
    return jnp.all((words & masks) == masks, axis=-1)


def _segment_add(spec: FilterSpec, seg_words: jnp.ndarray, keys: jnp.ndarray,
                 valid: jnp.ndarray, blocks_per_seg: int) -> jnp.ndarray:
    starts, masks = _local_fingerprints(spec, keys, blocks_per_seg)
    masks = masks * valid[:, None].astype(jnp.uint32)
    idx = (starts[:, None] + jnp.arange(spec.s, dtype=jnp.int32)[None, :]).reshape(-1)
    vals = masks.reshape(-1)
    acc = seg_words
    for b in range(WORD_BITS):                                # bit-plane OR scatter
        plane = (vals >> jnp.uint32(b)) & jnp.uint32(1)
        cnt = jnp.zeros_like(seg_words).at[idx].add(plane)
        acc = acc | ((cnt > 0).astype(jnp.uint32) << jnp.uint32(b))
    return acc


# ---------------------------------------------------------------------------
# Replicated deployment — pure transforms
# ---------------------------------------------------------------------------

def replicated_init(spec: FilterSpec, mesh: Mesh, axis: str = "data"
                    ) -> jnp.ndarray:
    """(n_dev, n_words) zeroed replicas, one per device along ``axis``."""
    n_dev = mesh.shape[axis]
    sharding = NamedSharding(mesh, P(axis))
    return jax.device_put(jnp.zeros((n_dev, spec.n_words), jnp.uint32),
                          sharding)


def replicated_add_local(spec: FilterSpec, mesh: Mesh, axis: str,
                         words: jnp.ndarray, keys_sharded: jnp.ndarray
                         ) -> jnp.ndarray:
    """Each device ORs its (n_dev, n_local, 2) key shard into its replica —
    no collectives; replicas diverge until the next OR-merge."""
    def body(w, keys):
        return V.add_scatter(spec, w[0], keys[0])[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded)


def replicated_sync(spec: FilterSpec, mesh: Mesh, axis: str,
                    words: jnp.ndarray, method: str = "butterfly"
                    ) -> jnp.ndarray:
    """Merge replicas: afterwards every device's replica is the global OR."""
    def body(w):
        return or_allreduce(w, axis, method=method)

    fn = shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    return fn(words)


def replicated_contains_local(spec: FilterSpec, mesh: Mesh, axis: str,
                              words: jnp.ndarray, keys_sharded: jnp.ndarray
                              ) -> jnp.ndarray:
    """Test each device's key shard against its *own* replica (pre-sync view:
    remote adds since the last sync are invisible)."""
    def body(w, keys):
        return V.contains(spec, w[0], keys[0])[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded)


def replicated_contains_merged(spec: FilterSpec, mesh: Mesh, axis: str,
                               words: jnp.ndarray, keys_sharded: jnp.ndarray
                               ) -> jnp.ndarray:
    """Test against the OR of all replicas (one butterfly per call) — the
    no-false-negative view the uniform Filter protocol promises, without
    mutating the replicas themselves."""
    def body(w, keys):
        merged = or_allreduce(w[0], axis)
        return V.contains(spec, merged, keys[0])[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded)


# ---------------------------------------------------------------------------
# Sharded deployment — pure transforms
# ---------------------------------------------------------------------------

def sharded_init(spec: FilterSpec, mesh: Mesh, axis: str = "data"
                 ) -> jnp.ndarray:
    """(n_words,) zeroed filter, block-range sharded along ``axis``."""
    n_dev = mesh.shape[axis]
    assert spec.n_blocks % n_dev == 0
    assert (n_dev & (n_dev - 1)) == 0, "device count must be pow2 (segments)"
    sharding = NamedSharding(mesh, P(axis))
    return jax.device_put(jnp.zeros((spec.n_words,), jnp.uint32), sharding)


def _route(spec: FilterSpec, keys: jnp.ndarray, n_dev: int, capacity: int):
    """Per-device: bucket local keys by owner segment, fixed capacity.

    Returns (send [n_dev, cap, 2], valid [n_dev, cap], seg, rank, keep).
    """
    blocks_per_seg = spec.n_blocks // n_dev
    n = keys.shape[0]
    h2 = H.xxh32_u64x2(keys, H.SEED_BLOCK)
    blk = H.block_index(h2, spec.n_blocks)
    seg = (blk // jnp.uint32(blocks_per_seg)).astype(jnp.int32)
    order = jnp.argsort(seg, stable=True)
    sorted_seg = seg[order]
    idx_in_run = (jnp.arange(n)
                  - jnp.searchsorted(sorted_seg, sorted_seg, side="left"))
    rank = jnp.zeros((n,), jnp.int32).at[order].set(idx_in_run.astype(jnp.int32))
    keep = rank < capacity
    slot = jnp.where(keep, seg * capacity + rank, n_dev * capacity)
    send = jnp.zeros((n_dev * capacity + 1, 2), jnp.uint32).at[slot].set(
        keys, mode="drop")[:-1].reshape(n_dev, capacity, 2)
    valid = jnp.zeros((n_dev * capacity + 1,), jnp.uint8).at[slot].set(
        1, mode="drop")[:-1].reshape(n_dev, capacity)
    return send, valid, seg, rank, keep


def sharded_add(spec: FilterSpec, mesh: Mesh, axis: str, capacity: int,
                words: jnp.ndarray, keys_sharded: jnp.ndarray) -> jnp.ndarray:
    """Route each device's (n_dev, n_local, 2) key shard to its segment owner
    (all_to_all), then bit-plane OR into the owner's resident segment."""
    n_dev = mesh.shape[axis]
    bps = spec.n_blocks // n_dev

    def body(w, keys):
        send, valid, *_ = _route(spec, keys[0], n_dev, capacity)
        recv_k = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        recv_v = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
        flat_k = recv_k.reshape(-1, 2)
        flat_v = recv_v.reshape(-1)
        return _segment_add(spec, w, flat_k, flat_v, bps)

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded)


# ---------------------------------------------------------------------------
# Bank-sharded deployment — the bank axis across the mesh (FilterBank)
# ---------------------------------------------------------------------------
# Device d owns B/n_dev whole member filters (each VMEM-small — exactly the
# multi-tenant regime the paper's cache-resident fast path wants). Routed
# ops compose TENANT routing with the existing key-routing machinery: keys
# ride a fixed-capacity all_to_all to their member's owner device, the
# owner runs the fused local bank op (core.variants.bank_*), and lookup
# results ride the inverse all_to_all home. Same conservative overflow
# contract as the block-sharded filter: overflowed adds drop (missed
# dedup), overflowed lookups report "present" (an allowed FP, never an FN).


def bankshard_init(spec: FilterSpec, mesh: Mesh, axis: str, bank: int
                   ) -> jnp.ndarray:
    """(bank, n_words) zeroed members, bank axis sharded along ``axis``."""
    n_dev = mesh.shape[axis]
    assert bank % n_dev == 0, (bank, n_dev)
    sharding = NamedSharding(mesh, P(axis))
    return jax.device_put(jnp.zeros((bank, spec.n_words), jnp.uint32),
                          sharding)


def _route_members(keys: jnp.ndarray, member: jnp.ndarray,
                   valid, n_dev: int, b_local: int, capacity: int):
    """Per-device: bucket local (key, member) pairs by owner device
    (member // b_local), fixed capacity. Members are rebased to the
    owner's local index before the send.

    The bucket-rank/scatter machinery is ``core.partition.route_by_id``
    (one implementation of the idiom); this adds only the member-rebase
    scatter and the caller-validity mask. Returns (send_k [n_dev, cap, 2],
    send_m [n_dev, cap], send_v [n_dev, cap], dest, rank, keep)."""
    from repro.core.partition import route_by_id
    member = jnp.asarray(member, jnp.int32)
    dest = member // jnp.int32(b_local)
    part = route_by_id(keys, dest, n_dev, capacity)
    # caller-invalid keys still travel in send_k (shape is fixed) but with
    # send_v = 0 they are masked no-ops at the owner
    ok = part.keep if valid is None else (part.keep & (valid > 0))
    slot = jnp.where(ok, dest * capacity + part.rank, n_dev * capacity)
    send_m = jnp.zeros((n_dev * capacity + 1,), jnp.int32).at[slot].set(
        member % jnp.int32(b_local), mode="drop")[:-1].reshape(n_dev, capacity)
    send_v = jnp.zeros((n_dev * capacity + 1,), jnp.uint8).at[slot].set(
        1, mode="drop")[:-1].reshape(n_dev, capacity)
    return part.keys_by_seg, send_m, send_v, dest, part.rank, part.keep


def bankshard_add(spec: FilterSpec, mesh: Mesh, axis: str, capacity: int,
                  words: jnp.ndarray, keys_sharded: jnp.ndarray,
                  member_sharded: jnp.ndarray, valid_sharded: jnp.ndarray
                  ) -> jnp.ndarray:
    """Route each device's flat (keys, member, valid) shard to the member's
    owner, then one fused bank add into the owner's resident members."""
    n_dev = mesh.shape[axis]
    b_local = words.shape[0] // n_dev

    def body(w, keys, member, valid):
        send_k, send_m, send_v, *_ = _route_members(
            keys[0], member[0], valid[0], n_dev, b_local, capacity)
        rk = jax.lax.all_to_all(send_k, axis, 0, 0, tiled=False)
        rm = jax.lax.all_to_all(send_m, axis, 0, 0, tiled=False)
        rv = jax.lax.all_to_all(send_v, axis, 0, 0, tiled=False)
        return V.bank_add_rows(spec, w, rk.reshape(-1, 2), rm.reshape(-1),
                               valid=rv.reshape(-1))

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded, member_sharded, valid_sharded)


def bankshard_contains(spec: FilterSpec, mesh: Mesh, axis: str,
                       capacity: int, words: jnp.ndarray,
                       keys_sharded: jnp.ndarray,
                       member_sharded: jnp.ndarray) -> jnp.ndarray:
    """(n_dev, n_local) bool, sharded like the keys; each key tested only
    against its member's filter. Overflowed keys report "present"."""
    n_dev = mesh.shape[axis]
    b_local = words.shape[0] // n_dev

    def body(w, keys, member):
        k, m = keys[0], member[0]
        send_k, send_m, _, dest, rank, keep = _route_members(
            k, m, None, n_dev, b_local, capacity)
        rk = jax.lax.all_to_all(send_k, axis, 0, 0, tiled=False)
        rm = jax.lax.all_to_all(send_m, axis, 0, 0, tiled=False)
        res = V.bank_contains_rows(spec, w, rk.reshape(-1, 2),
                                   rm.reshape(-1))
        back = jax.lax.all_to_all(res.reshape(n_dev, capacity), axis, 0, 0,
                                  tiled=False)
        mine = back.reshape(-1)[dest * capacity + rank]
        return jnp.where(keep, mine, True)[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded, member_sharded)


def sharded_contains(spec: FilterSpec, mesh: Mesh, axis: str, capacity: int,
                     words: jnp.ndarray, keys_sharded: jnp.ndarray
                     ) -> jnp.ndarray:
    """Returns (n_dev, n_local) bool, sharded like the keys. Overflowed keys
    conservatively report "present" (allowed FP, never an FN)."""
    n_dev = mesh.shape[axis]
    bps = spec.n_blocks // n_dev

    def body(w, keys):
        k = keys[0]
        send, valid, seg, rank, keep = _route(spec, k, n_dev, capacity)
        recv_k = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        res = _segment_contains(spec, w, recv_k.reshape(-1, 2), bps)
        res = res.reshape(n_dev, capacity)
        back = jax.lax.all_to_all(res, axis, 0, 0, tiled=False)  # (n_dev, cap)
        mine = back.reshape(-1)[seg * capacity + rank]
        return jnp.where(keep, mine, True)[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P(axis))
    return fn(words, keys_sharded)


