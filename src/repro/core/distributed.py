"""Distributed Bloom filters over a JAX device mesh.

Two deployment shapes, both built on ``shard_map``:

``ReplicatedFilter``
    Every device holds the full word array; adds are applied locally to the
    device's replica against its own key shard, and ``sync()`` merges the
    replicas with a **butterfly OR all-reduce** built from ``lax.ppermute``
    (bitwise OR is not a native JAX collective — log2(n) rounds, each moving
    m bits, same volume schedule as a bidirectional-ring all-reduce for
    small device counts). Between syncs the filter is eventually-consistent:
    a duplicate may slip through, the FPR is unaffected — the right trade
    for data-pipeline dedup where a missed duplicate costs one wasted
    sample, not correctness.

``ShardedFilter``
    The word array is split into per-device **segments** (contiguous block
    ranges — the distributed extension of the ownership model in
    core.partition). Bulk ops route each key to its segment owner with a
    fixed-capacity ``all_to_all`` (GShard-style: static capacity + validity
    mask), the owner runs the single-core op on its VMEM-resident segment,
    and lookup results ride the inverse all_to_all home. Capacity overflow
    degrades *conservatively*: an overflowed lookup reports "present" (an
    allowed false positive — never a false negative) and an overflowed add
    is dropped (a missed dedup, not a correctness bug).

Scale note (1000+ nodes): ShardedFilter keeps per-device memory at m/n and
turns the paper's DRAM-random-access bound into a VMEM-resident-segment
workload — the multi-device generalization of the paper's cache-resident
fast path.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hashing as H
from repro.core import variants as V
from repro.core.variants import FilterSpec, WORD_BITS


# ---------------------------------------------------------------------------
# Butterfly OR all-reduce (custom collective)
# ---------------------------------------------------------------------------

def or_allreduce(x: jnp.ndarray, axis_name: str, method: str = "butterfly"
                 ) -> jnp.ndarray:
    """Bitwise-OR all-reduce along a mesh axis (inside shard_map).

    butterfly: log2(n) ppermute rounds (n must be a power of two).
    gather:    all_gather + local OR fold (any n; more memory).
    """
    n = jax.lax.axis_size(axis_name)
    if method == "gather" or (n & (n - 1)) != 0:
        g = jax.lax.all_gather(x, axis_name, axis=0)         # (n, ...)
        acc = g[0]
        for i in range(1, n):                                 # static fold
            acc = acc | g[i]
        return acc
    step = 1
    while step < n:
        perm = [(i, i ^ step) for i in range(n)]
        x = x | jax.lax.ppermute(x, axis_name, perm)
        step <<= 1
    return x


# ---------------------------------------------------------------------------
# Localized single-device ops on a filter *segment*
# ---------------------------------------------------------------------------

def _local_fingerprints(spec: FilterSpec, keys: jnp.ndarray, blocks_per_seg: int):
    """(local word starts, masks) for keys known to belong to this segment."""
    h1 = H.xxh32_u64x2(keys, H.SEED_PATTERN)
    h2 = H.xxh32_u64x2(keys, H.SEED_BLOCK)
    blk = H.block_index(h2, spec.n_blocks)
    blk_local = blk & jnp.uint32(blocks_per_seg - 1)
    masks = V.block_patterns(spec, h1)
    starts = (blk_local * jnp.uint32(spec.s)).astype(jnp.int32)
    return starts, masks


def _segment_contains(spec: FilterSpec, seg_words: jnp.ndarray,
                      keys: jnp.ndarray, blocks_per_seg: int) -> jnp.ndarray:
    starts, masks = _local_fingerprints(spec, keys, blocks_per_seg)
    idx = starts[:, None] + jnp.arange(spec.s, dtype=jnp.int32)[None, :]
    words = seg_words[idx]
    return jnp.all((words & masks) == masks, axis=-1)


def _segment_add(spec: FilterSpec, seg_words: jnp.ndarray, keys: jnp.ndarray,
                 valid: jnp.ndarray, blocks_per_seg: int) -> jnp.ndarray:
    starts, masks = _local_fingerprints(spec, keys, blocks_per_seg)
    masks = masks * valid[:, None].astype(jnp.uint32)
    idx = (starts[:, None] + jnp.arange(spec.s, dtype=jnp.int32)[None, :]).reshape(-1)
    vals = masks.reshape(-1)
    acc = seg_words
    for b in range(WORD_BITS):                                # bit-plane OR scatter
        plane = (vals >> jnp.uint32(b)) & jnp.uint32(1)
        cnt = jnp.zeros_like(seg_words).at[idx].add(plane)
        acc = acc | ((cnt > 0).astype(jnp.uint32) << jnp.uint32(b))
    return acc


# ---------------------------------------------------------------------------
# ReplicatedFilter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplicatedFilter:
    spec: FilterSpec
    mesh: Mesh
    axis: str
    words: jnp.ndarray                    # (n_dev, n_words): one replica per device
    pending_syncs: int = 0

    @classmethod
    def create(cls, spec: FilterSpec, mesh: Mesh, axis: str = "data"):
        n_dev = mesh.shape[axis]
        sharding = NamedSharding(mesh, P(axis))
        words = jax.device_put(jnp.zeros((n_dev, spec.n_words), jnp.uint32),
                               sharding)
        return cls(spec=spec, mesh=mesh, axis=axis, words=words)

    def add_local(self, keys_sharded: jnp.ndarray) -> "ReplicatedFilter":
        """keys_sharded: (n_dev, n_local, 2) sharded on axis 0 — each device
        ORs its key shard into its own replica (no collectives)."""
        spec = self.spec

        def body(words, keys):
            return V.add_scatter(spec, words[0], keys[0])[None]

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=(P(self.axis), P(self.axis)),
                       out_specs=P(self.axis))
        self.words = fn(self.words, keys_sharded)
        self.pending_syncs += 1
        return self

    def sync(self, method: str = "butterfly") -> "ReplicatedFilter":
        """Merge replicas: after this, every device's replica is the global OR."""
        def body(words):
            return or_allreduce(words, self.axis, method=method)

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=P(self.axis), out_specs=P(self.axis))
        self.words = fn(self.words)
        self.pending_syncs = 0
        return self

    def contains_local(self, keys_sharded: jnp.ndarray) -> jnp.ndarray:
        spec = self.spec

        def body(words, keys):
            return V.contains(spec, words[0], keys[0])[None]

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=(P(self.axis), P(self.axis)),
                       out_specs=P(self.axis))
        return fn(self.words, keys_sharded)

    def global_words(self) -> jnp.ndarray:
        """Host view of replica 0 (call after sync() for the global filter)."""
        return self.words[0]


# ---------------------------------------------------------------------------
# ShardedFilter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedFilter:
    spec: FilterSpec
    mesh: Mesh
    axis: str
    words: jnp.ndarray                    # (n_words,) sharded on `axis`
    capacity: int                         # per (src, dst) routing capacity

    @classmethod
    def create(cls, spec: FilterSpec, mesh: Mesh, axis: str = "data",
               capacity: int = 1024):
        n_dev = mesh.shape[axis]
        assert spec.n_blocks % n_dev == 0
        assert (n_dev & (n_dev - 1)) == 0, "device count must be pow2 (segments)"
        sharding = NamedSharding(mesh, P(axis))
        words = jax.device_put(jnp.zeros((spec.n_words,), jnp.uint32), sharding)
        return cls(spec=spec, mesh=mesh, axis=axis, words=words,
                   capacity=capacity)

    @property
    def n_dev(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def blocks_per_seg(self) -> int:
        return self.spec.n_blocks // self.n_dev

    def _route(self, keys: jnp.ndarray):
        """Per-device: bucket local keys by owner segment, fixed capacity.

        Returns (send [n_dev, cap, 2], valid [n_dev, cap], seg, rank, keep).
        """
        spec, n_dev, cap = self.spec, self.n_dev, self.capacity
        n = keys.shape[0]
        h2 = H.xxh32_u64x2(keys, H.SEED_BLOCK)
        blk = H.block_index(h2, spec.n_blocks)
        seg = (blk // jnp.uint32(self.blocks_per_seg)).astype(jnp.int32)
        order = jnp.argsort(seg, stable=True)
        sorted_seg = seg[order]
        idx_in_run = (jnp.arange(n)
                      - jnp.searchsorted(sorted_seg, sorted_seg, side="left"))
        rank = jnp.zeros((n,), jnp.int32).at[order].set(idx_in_run.astype(jnp.int32))
        keep = rank < cap
        slot = jnp.where(keep, seg * cap + rank, n_dev * cap)
        send = jnp.zeros((n_dev * cap + 1, 2), jnp.uint32).at[slot].set(
            keys, mode="drop")[:-1].reshape(n_dev, cap, 2)
        valid = jnp.zeros((n_dev * cap + 1,), jnp.uint8).at[slot].set(
            1, mode="drop")[:-1].reshape(n_dev, cap)
        return send, valid, seg, rank, keep

    def add(self, keys_sharded: jnp.ndarray) -> "ShardedFilter":
        """keys_sharded: (n_dev, n_local, 2) sharded on axis 0."""
        spec, axis, bps = self.spec, self.axis, self.blocks_per_seg

        def body(words, keys):
            send, valid, *_ = self._route(keys[0])
            recv_k = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
            recv_v = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
            flat_k = recv_k.reshape(-1, 2)
            flat_v = recv_v.reshape(-1)
            return _segment_add(spec, words, flat_k, flat_v, bps)

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=(P(axis), P(axis)),
                       out_specs=P(axis))
        self.words = fn(self.words, keys_sharded)
        return self

    def contains(self, keys_sharded: jnp.ndarray) -> jnp.ndarray:
        """Returns (n_dev, n_local) bool, sharded like the keys."""
        spec, axis, bps, n_dev, cap = (self.spec, self.axis,
                                       self.blocks_per_seg, self.n_dev,
                                       self.capacity)

        def body(words, keys):
            k = keys[0]
            send, valid, seg, rank, keep = self._route(k)
            recv_k = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
            res = _segment_contains(spec, words, recv_k.reshape(-1, 2), bps)
            res = res.reshape(n_dev, cap)
            back = jax.lax.all_to_all(res, axis, 0, 0, tiled=False)  # (n_dev, cap)
            mine = back.reshape(-1)[seg * cap + rank]
            # overflowed keys: conservatively report "present" (allowed FP)
            return jnp.where(keep, mine, True)[None]

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=(P(axis), P(axis)),
                       out_specs=P(axis))
        return fn(self.words, keys_sharded)

    def fill_fraction(self) -> float:
        return float(V.fill_fraction(self.words))
