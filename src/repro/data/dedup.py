"""Bloom-filter training-data dedup — the paper's technique as a pipeline stage.

Each document is folded to a 64-bit signature (numpy, host-side) and tested
against / inserted into a Bloom filter via the **bulk** contains/add ops the
paper optimizes. The filter is a :class:`repro.api.Filter`, so the same
``DedupFilter`` stage runs on any registry engine: pass
``backend="sharded", mesh=...`` for multi-host pipelines, ``"pallas-vmem"``
on TPU, etc. Documents are buffered and deduped in bulk (amortizing kernel
launches exactly as the paper's bulk APIs do).

Bloom semantics for dedup: a false positive drops a *unique* document
(bounded by the filter's FPR — pick c accordingly); a false negative never
happens, so no duplicate is ever *guaranteed* through. Near-duplicates are
out of scope (signature equality = exact token match).

Two deployment shapes:

* :class:`DedupFilter` — insert-only, exact over the whole corpus; right
  when the corpus is bounded and sized for up front.
* :class:`StreamingDedupFilter` — **sliding-window dedup with eviction**
  over a :class:`repro.window.WindowedFilter` generation ring: duplicates
  are dropped only while their first occurrence is within the last
  ``window_docs`` documents; older signatures are retired in O(1) by
  ring advances, so memory and FPR stay bounded on an *unbounded* stream
  (the insert-only filter would saturate and drop everything).
* :class:`TenantDedupFilter` — **per-tenant dedup over a FilterBank**:
  tenant t's documents dedup only against tenant t's history. One bank of
  T VMEM-small member filters, and each batch is ONE routed
  ``contains(keys, tenants)`` + ONE valid-masked routed ``add`` — no
  per-tenant Python loop, no cross-tenant signature collisions.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro import api
from repro.window import WindowedFilter


def doc_signature(tokens: np.ndarray) -> np.ndarray:
    """Fold a token array to a u64x2 signature (2 independent 32-bit mixes)."""
    t = np.asarray(tokens, dtype=np.uint32)
    h1 = np.uint32(0x811C9DC5)
    h2 = np.uint32(0x9E3779B9)
    with np.errstate(over="ignore"):
        # vectorized polynomial fold: h = sum t_i * P^i  (two prime bases),
        # then avalanche. Associative-friendly and order-sensitive.
        p1 = np.uint32(16777619)
        p2 = np.uint32(2246822519)
        w1 = np.cumprod(np.full(len(t), p1, np.uint32))
        w2 = np.cumprod(np.full(len(t), p2, np.uint32))
        h1 = h1 + np.uint32(np.sum(t * w1, dtype=np.uint64) & np.uint64(0xFFFFFFFF))
        h2 = h2 + np.uint32(np.sum(t * w2, dtype=np.uint64) & np.uint64(0xFFFFFFFF))
        h1 ^= np.uint32(len(t)); h1 *= np.uint32(2654435761); h1 ^= h1 >> np.uint32(16)
        h2 ^= np.uint32(len(t)); h2 *= np.uint32(3266489917); h2 ^= h2 >> np.uint32(15)
    return np.array([h1, h2], dtype=np.uint32)


def doc_signatures_batch(docs) -> np.ndarray:
    """Vectorized (n, 2) u64x2 signatures for a list of token arrays.

    Bit-exact with per-doc ``doc_signature``: zero-padding beyond each doc's
    length contributes nothing to the polynomial fold, and the length is
    mixed in explicitly."""
    n = len(docs)
    lens = np.array([len(d) for d in docs], np.uint32)
    L = max(int(lens.max()), 1)
    mat = np.zeros((n, L), np.uint32)
    for i, d in enumerate(docs):
        mat[i, : len(d)] = np.asarray(d, dtype=np.uint32)
    with np.errstate(over="ignore"):
        w1 = np.cumprod(np.full(L, 16777619, np.uint32))
        w2 = np.cumprod(np.full(L, 2246822519, np.uint32))
        h1 = np.uint32(0x811C9DC5) + (
            (mat * w1).sum(axis=1, dtype=np.uint64)
            & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        h2 = np.uint32(0x9E3779B9) + (
            (mat * w2).sum(axis=1, dtype=np.uint64)
            & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        h1 ^= lens; h1 *= np.uint32(2654435761); h1 ^= h1 >> np.uint32(16)
        h2 ^= lens; h2 *= np.uint32(3266489917); h2 ^= h2 >> np.uint32(15)
    return np.stack([h1, h2], axis=-1)


def ngram_signatures(tokens: np.ndarray, n: int = 8, stride: int = 4
                     ) -> np.ndarray:
    """(k, 2) u64x2 signatures of overlapping n-grams (contamination checks)."""
    t = np.asarray(tokens, dtype=np.uint32)
    if len(t) < n:
        return doc_signature(t)[None]
    starts = range(0, len(t) - n + 1, stride)
    return np.stack([doc_signature(t[s: s + n]) for s in starts])


@dataclasses.dataclass
class DedupStats:
    seen: int = 0
    dropped: int = 0

    @property
    def drop_rate(self) -> float:
        return self.dropped / max(self.seen, 1)


class DedupFilter:
    """Bulk Bloom dedup over a document stream."""

    def __init__(self, expected_docs: int = 1 << 20, bits_per_key: float = 16.0,
                 variant: str = "sbf", block_bits: int = 256,
                 backend: str = "auto", batch_docs: int = 256, **backend_kw):
        self.filt = api.filter_for_n_items(expected_docs, bits_per_key,
                                           variant=variant,
                                           block_bits=block_bits,
                                           backend=backend, **backend_kw)
        self.batch_docs = batch_docs
        self.stats = DedupStats()

    def filter_stream(self, docs: Iterator[np.ndarray]) -> Iterator[np.ndarray]:
        buf: List[np.ndarray] = []
        for doc in docs:
            buf.append(doc)
            if len(buf) >= self.batch_docs:
                yield from self._flush(buf)
                buf = []
        if buf:
            yield from self._flush(buf)

    def _flush(self, docs: List[np.ndarray]):
        sigs = doc_signatures_batch(docs)                        # (n, 2)
        # bulk lookup, then bulk insert of the new ones (paper's bulk ops)
        present = np.asarray(self.filt.contains(sigs))
        fresh_idx = np.nonzero(~present)[0]
        if len(fresh_idx):
            # de-dup *within* the batch as well (first occurrence wins)
            seen_in_batch = {}
            keep = []
            for i in fresh_idx:
                key = sigs[i].tobytes()
                if key not in seen_in_batch:
                    seen_in_batch[key] = True
                    keep.append(i)
            # pad to the batch capacity (OR is idempotent) -> stable shapes,
            # no per-flush retrace
            add_sigs = sigs[np.array(keep)]
            pad = self.batch_docs - len(add_sigs)
            if pad > 0:
                add_sigs = np.concatenate(
                    [add_sigs, np.repeat(add_sigs[-1:], pad, axis=0)])
            self.filt = self.filt.add(add_sigs)
            kept = set(keep)
        else:
            kept = set()
        self.stats.seen += len(docs)
        self.stats.dropped += len(docs) - len(kept)
        for i in sorted(kept):
            yield docs[i]


@dataclasses.dataclass
class StreamingDedupStats(DedupStats):
    advances: int = 0     # generations retired (evictions happen here)


class StreamingDedupFilter:
    """Sliding-window dedup over an unbounded stream, with eviction.

    Two eviction engines behind one stream interface:

    * ``engine="window"`` (default) — a :class:`repro.window.WindowedFilter`
      generation ring: signatures land in the head generation, lookups OR
      the ring in one fused pass, and every ``window_docs / generations``
      admitted documents the ring advances, retiring the oldest
      generation (an *age class*) in O(1).
    * ``engine="cuckoo"`` — a fingerprint filter (``variant="cuckoo"``):
      the window's signatures are deleted *per key* via
      ``Filter.remove`` instead of by age-class rotation. One table
      (~slot_bits/0.95 bits per live key — no G-generation replication,
      half to a quarter of a 4-bit counting filter), and eviction is
      exact: a retired signature is individually cleared, not ORed away
      with its whole generation. The stage keeps the retiring
      generation's signatures host-side (it must know *what* to delete —
      the fingerprint filter trades that bookkeeping for the memory).

    Memory and FPR are stationary on an unbounded stream either way.
    Within the live window the no-false-negative guarantee holds: a
    duplicate of a document seen fewer than ``window_docs`` (at least
    ``window_docs * (G-1)/G``) documents ago is always dropped.
    """

    def __init__(self, window_docs: int = 1 << 16, generations: int = 4,
                 bits_per_key: float = 16.0, variant: str = "sbf",
                 block_bits: int = 256, batch_docs: int = 256,
                 engine: str = "window"):
        if engine not in ("window", "cuckoo"):
            raise ValueError(f"engine must be 'window' or 'cuckoo': {engine}")
        self.engine = engine
        self.generations = generations
        self.batch_docs = batch_docs
        self.advance_every = max(window_docs // generations, 1)
        self._since_advance = 0
        self.stats = StreamingDedupStats()
        if engine == "window":
            self.window = WindowedFilter.for_window(
                window_docs, bits_per_key=bits_per_key,
                generations=generations, variant=variant,
                block_bits=block_bits)
        else:
            # live load peaks at the full window plus the not-yet-retired
            # newest generation; size the table so that stays under the
            # 0.95 achievable load factor
            self.filt = api.filter_for_n_items(
                window_docs + self.advance_every, bits_per_key=bits_per_key,
                variant="cuckoo")
            self._gens: List[List[np.ndarray]] = []   # admitted, oldest first
            self._cur: List[np.ndarray] = []          # filling generation

    def filter_stream(self, docs: Iterator[np.ndarray]) -> Iterator[np.ndarray]:
        buf: List[np.ndarray] = []
        for doc in docs:
            buf.append(doc)
            if len(buf) >= self.batch_docs:
                yield from self._flush(buf)
                buf = []
        if buf:
            yield from self._flush(buf)

    def _contains(self, sigs: np.ndarray) -> np.ndarray:
        filt = self.window if self.engine == "window" else self.filt
        return np.asarray(filt.contains(sigs))

    def _admit(self, add_sigs: np.ndarray):
        pad = self.batch_docs - len(add_sigs)
        if self.engine == "window":
            # ring generations are bit filters: repeat-key padding stays
            # OR-idempotent (stable shapes, no per-flush retrace)
            if pad > 0:
                add_sigs = np.concatenate(
                    [add_sigs, np.repeat(add_sigs[-1:], pad, axis=0)])
            self.window = self.window.add(add_sigs)
            return
        # fingerprint inserts are NOT idempotent: pad with a validity mask
        valid = np.zeros(max(self.batch_docs, len(add_sigs)), np.uint8)
        valid[: len(add_sigs)] = 1
        if pad > 0:
            add_sigs = np.concatenate(
                [add_sigs, np.zeros((pad, 2), np.uint32)])
        self.filt = self.filt.add(add_sigs, valid=valid)
        self._cur.append(add_sigs[valid.astype(bool)])

    def _advance(self):
        """Retire the oldest generation: ring rotation, or per-key
        fingerprint deletion of exactly the signatures it admitted.

        Mirrors the ring's shape: after an advance the live window is the
        (empty) head plus ``generations - 1`` completed age classes."""
        if self.engine == "window":
            self.window = self.window.advance()
            return
        self._gens.append(self._cur)
        self._cur = []
        while len(self._gens) > self.generations - 1:
            old = self._gens.pop(0)
            if not old:
                continue
            sigs = np.concatenate(old)
            # pad to the next pow2 (bounded retrace) with a valid mask —
            # fingerprint removes are not idempotent either
            cap = 1 << max(int(np.ceil(np.log2(max(len(sigs), 1)))), 3)
            valid = np.zeros(cap, np.uint8)
            valid[: len(sigs)] = 1
            sigs = np.concatenate(
                [sigs, np.zeros((cap - len(sigs), 2), np.uint32)])
            self.filt = self.filt.remove(sigs, valid=valid)

    def _flush(self, docs: List[np.ndarray]):
        sigs = doc_signatures_batch(docs)                        # (n, 2)
        present = self._contains(sigs)
        fresh_idx = np.nonzero(~present)[0]
        kept = set()
        if len(fresh_idx):
            seen_in_batch = {}
            keep = []
            for i in fresh_idx:
                key = sigs[i].tobytes()
                if key not in seen_in_batch:
                    seen_in_batch[key] = True
                    keep.append(i)
            self._admit(sigs[np.array(keep)])
            kept = set(keep)
        self.stats.seen += len(docs)
        self.stats.dropped += len(docs) - len(kept)
        # advance on *admitted* docs: the window is measured in kept load
        self._since_advance += len(kept)
        while self._since_advance >= self.advance_every:
            self._advance()
            self.stats.advances += 1
            self._since_advance -= self.advance_every
        for i in sorted(kept):
            yield docs[i]


class TenantDedupFilter:
    """Per-tenant bulk dedup over one :func:`repro.api.make_filter_bank`.

    Every document carries a tenant id in ``[0, n_tenants)``; a duplicate
    is dropped only if the *same tenant* saw the signature before. The
    whole batch runs as one routed bank lookup plus one valid-masked
    routed bank add (tenant routing composed into the kernel's member
    offset on native engines — no scatter, no host loop). Pass
    ``backend="sharded", mesh=...`` to shard the *bank axis* across a
    mesh: each device owns ``n_tenants / n_dev`` whole member filters and
    tenant routing rides the same all_to_all as the key routing.
    """

    def __init__(self, n_tenants: int, expected_docs_per_tenant: int = 1 << 14,
                 bits_per_key: float = 16.0, variant: str = "sbf",
                 block_bits: int = 256, backend: str = "auto",
                 batch_docs: int = 256, engine: Optional[str] = None,
                 **backend_kw):
        if engine == "cuckoo":
            # fingerprint bank: per-tenant deletion at ~1x storage becomes
            # available (filt.remove(keys, tenants=...)) and the routed
            # adds below are already valid-masked — the exact padding
            # contract non-idempotent fingerprint inserts require
            variant = "cuckoo"
        elif engine == "counting":
            variant = "countingbf"
        elif engine is not None:
            raise ValueError(
                f"engine must be 'cuckoo', 'counting' or None (insert-only"
                f" bit filters via variant=/backend=): {engine!r}")
        self.filt = api.filter_for_n_items(
            expected_docs_per_tenant, bits_per_key, variant=variant,
            block_bits=block_bits, backend=backend, bank=n_tenants,
            **backend_kw)
        self.n_tenants = n_tenants
        self.batch_docs = batch_docs
        self.stats = DedupStats()

    def dedupe_batch(self, docs: List[np.ndarray], tenants) -> List[int]:
        """Returns the indices of ``docs`` to keep (first tenant-local
        occurrence of each signature), updating the bank."""
        n = len(docs)
        sigs = doc_signatures_batch(docs)                        # (n, 2)
        t = np.asarray(tenants, np.int64).reshape(n)
        # pad to the batch capacity -> stable shapes, no per-flush retrace
        # (valid-masked adds make zero-padding exact; padded lookups are
        # sliced off by the routed contains itself)
        pad = self.batch_docs - n
        if pad > 0:
            sigs_p = np.concatenate([sigs, np.zeros((pad, 2), np.uint32)])
            t_p = np.concatenate([t, np.zeros(pad, np.int64)])
        else:
            sigs_p, t_p = sigs, t
        present = np.asarray(self.filt.contains(sigs_p, tenants=t_p))[:n]
        # in-batch dedup per (tenant, signature): first occurrence wins
        rows = np.concatenate([t[:, None].astype(np.uint32), sigs], axis=1)
        _, first_idx = np.unique(rows, axis=0, return_index=True)
        first = np.zeros(n, bool)
        first[first_idx] = True
        keep = (~present) & first
        valid = np.zeros(self.batch_docs if pad > 0 else n, np.uint8)
        valid[:n] = keep
        self.filt = self.filt.add(sigs_p, tenants=t_p, valid=valid)
        self.stats.seen += n
        self.stats.dropped += int(n - keep.sum())
        return [i for i in range(n) if keep[i]]

    def filter_stream(self, docs_with_tenants: Iterator) -> Iterator:
        """Stream of ``(doc, tenant_id)`` pairs -> kept pairs, batched."""
        buf: List = []
        for pair in docs_with_tenants:
            buf.append(pair)
            if len(buf) >= self.batch_docs:
                yield from self._flush(buf)
                buf = []
        if buf:
            yield from self._flush(buf)

    def _flush(self, pairs: List):
        docs = [d for d, _ in pairs]
        tenants = [t for _, t in pairs]
        for i in self.dedupe_batch(docs, tenants):
            yield pairs[i]
