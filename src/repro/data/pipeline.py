"""Data pipeline: synthetic corpus -> dedup -> packing -> global batches.

Host-side (numpy) by design: on a pod each process runs this pipeline over
its own corpus shard and feeds its addressable devices; the Bloom-filter
dedup stage (repro.data.dedup) is the paper's technique wired in as a
first-class pipeline stage.

The synthetic corpus deliberately injects near/exact duplicate documents at a
configurable rate so dedup efficacy is measurable (tests + examples).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class CorpusConfig:
    n_docs: int = 10_000
    vocab: int = 32_000
    doc_len_min: int = 32
    doc_len_max: int = 512
    dup_fraction: float = 0.2       # fraction of docs that are exact dups
    zipf_a: float = 1.3             # token distribution skew
    seed: int = 0


def synthetic_corpus(cfg: CorpusConfig, shard: int = 0, num_shards: int = 1
                     ) -> Iterator[np.ndarray]:
    """Yield token arrays (int32). Duplicates repeat earlier docs verbatim
    (possibly across shard boundaries — the realistic hard case for
    distributed dedup)."""
    rng = np.random.RandomState(cfg.seed + 7919 * shard)
    pool: List[np.ndarray] = []
    n_local = cfg.n_docs // num_shards
    for i in range(n_local):
        if pool and rng.rand() < cfg.dup_fraction:
            yield pool[rng.randint(len(pool))]
            continue
        ln = rng.randint(cfg.doc_len_min, cfg.doc_len_max + 1)
        doc = rng.zipf(cfg.zipf_a, size=ln).astype(np.int64)
        doc = (doc % (cfg.vocab - 2)) + 2           # 0=pad, 1=eos reserved
        doc = doc.astype(np.int32)
        pool.append(doc)
        yield doc


EOS = 1
PAD = 0


class Packer:
    """Greedy document packing into fixed (seq_len,) rows with EOS joints."""

    def __init__(self, seq_len: int):
        self.seq_len = seq_len
        self._buf = np.zeros((0,), np.int32)

    def feed(self, doc: np.ndarray) -> List[np.ndarray]:
        joined = np.concatenate([self._buf, doc, [EOS]])
        out = []
        while len(joined) >= self.seq_len:
            out.append(joined[: self.seq_len])
            joined = joined[self.seq_len:]
        self._buf = joined
        return out

    def flush(self) -> Optional[np.ndarray]:
        if len(self._buf) == 0:
            return None
        row = np.full((self.seq_len,), PAD, np.int32)
        row[: len(self._buf)] = self._buf
        self._buf = np.zeros((0,), np.int32)
        return row


def deduped_batches(cfg: CorpusConfig, batch_size: int, seq_len: int,
                    expected_docs: Optional[int] = None,
                    bits_per_key: float = 16.0, backend: str = "auto",
                    shard: int = 0, num_shards: int = 1, **backend_kw
                    ) -> Iterator[np.ndarray]:
    """corpus -> Bloom dedup -> packing, as one composed stage.

    The dedup filter is a ``repro.api`` filter, so ``backend=`` reaches the
    whole engine registry (e.g. ``backend="sharded", mesh=...`` dedups
    against one global filter across a pod)."""
    from repro.data.dedup import DedupFilter
    dd = DedupFilter(expected_docs=expected_docs or max(cfg.n_docs, 1024),
                     bits_per_key=bits_per_key, backend=backend, **backend_kw)
    docs = synthetic_corpus(cfg, shard=shard, num_shards=num_shards)
    yield from batches(dd.filter_stream(docs), batch_size, seq_len)


def batches(doc_iter: Iterator[np.ndarray], batch_size: int, seq_len: int
            ) -> Iterator[np.ndarray]:
    """Pack a doc stream into (batch_size, seq_len) int32 batches."""
    packer = Packer(seq_len)
    rows: List[np.ndarray] = []
    for doc in doc_iter:
        rows.extend(packer.feed(doc))
        while len(rows) >= batch_size:
            yield np.stack(rows[:batch_size])
            rows = rows[batch_size:]
    tail = packer.flush()
    if tail is not None:
        rows.append(tail)
    while len(rows) >= batch_size:
        yield np.stack(rows[:batch_size])
        rows = rows[batch_size:]
