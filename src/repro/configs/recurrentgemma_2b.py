"""RecurrentGemma-2B (Griffin) — hybrid RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427; hf-verified]
Pattern: (recurrent, recurrent, local_attn) cycled over 26 layers (the final
partial cycle — 2 recurrent layers — is handled as unrolled tail layers).
MQA (kv=1) on the attention layers, window 2048, lru_width = d_model = 2560.
Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    mlp="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    max_seq_len=1_048_576,
    tie_embeddings=True,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    rnn_width=2560,
    conv_width=4,
    sub_quadratic=True,
    source="arXiv:2402.19427; hf",
)
