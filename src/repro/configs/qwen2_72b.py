"""Qwen2-72B — dense GQA decoder with QKV bias; the largest assigned arch.

[arXiv:2407.10671; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    tie_embeddings=False,
    source="arXiv:2407.10671; hf",
)
