"""Mistral-Nemo-Base-2407 (12B) — dense GQA decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf-verified]
Note head_dim=128 with 32 heads (q proj 4096 < d_model 5120) per HF config.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Nemo-Base-2407; hf",
)
