"""Config dataclasses: architectures, input shapes, parallelism, training.

Every assigned architecture gets one ``ArchConfig`` in its own module under
``repro.configs``; the registry in ``repro.configs.__init__`` resolves
``--arch <id>``. Smoke tests run ``smoke_config(cfg)`` reductions; the full
configs are only ever lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    expert_d_ff: int            # hidden width per routed expert
    num_shared: int = 0         # always-on shared experts
    shared_d_ff: int = 0        # hidden width of the shared expert block
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|encdec_audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int             # == n_heads for MHA; 0 for attention-free layers
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp: str = "swiglu"         # swiglu|geglu|relu2
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    max_seq_len: int = 131072
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    n_dense_head: int = 0       # leading dense layers before MoE (DeepSeek: 1)
    # layer-type cycle, e.g. ("rglru","rglru","local_attn") for recurrentgemma
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 2048          # local_attn window
    rnn_width: Optional[int] = None   # RG-LRU lru width (defaults d_model)
    rnn_heads: int = 1          # RG-LRU block-diagonal heads / RWKV heads
    conv_width: int = 4         # temporal conv in recurrent block
    encoder_layers: int = 0     # enc-dec: encoder depth (decoder = n_layers)
    prefix_len: int = 256       # vlm/audio stub: prefix embedding positions
    frontend: str = "none"      # none|audio|vision (stubbed: precomputed embeds)
    source: str = ""            # provenance note [paper/hf; tier]
    sub_quadratic: bool = False # supports long_500k decode

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/logits shard over model=16
        (standard vocab padding; pad ids are never emitted as labels)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def pattern_for_layer(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_types(self) -> Tuple[str, ...]:
        return tuple(self.pattern_for_layer(i) for i in range(self.n_layers))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train|prefill|decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch, shape) cell runnable? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "skipped (full attention; no sub-quadratic path)"
    return True, ""


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a step is laid out on the mesh (see launch.shardings)."""
    data_axes: Tuple[str, ...] = ("pod", "data")   # batch sharding axes present in mesh
    model_axis: str = "model"
    zero1: bool = True           # shard optimizer state over data axes
    sequence_parallel: bool = False
    remat: str = "block"         # none|block — activation checkpoint per layer
    pipeline_stages: int = 1     # >1: GPipe over the leading data axis
    grad_compression: str = "none"  # none|int8_ef


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    param_dtype: str = "float32"     # master/runtime params
    compute_dtype: str = "bfloat16"
    label_smoothing: float = 0.0


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving reduction for CPU smoke tests.

    Keeps: block pattern cycle length, GQA ratio, MoE routing shape (fewer
    experts, same top_k semantics), enc-dec split, frontend kind.
    Shrinks: layers -> one pattern cycle (>=2), widths, vocab, experts.
    """
    n_layers = max(len(cfg.block_pattern), 2)
    if cfg.is_encdec:
        n_layers = 2
    n_heads = max(4, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)) if cfg.n_kv_heads else 0
    n_kv = max(1, n_heads // ratio) if cfg.n_kv_heads else 0
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.shared_d_ff else 0)
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=256,
        vocab=512,
        head_dim=32,
        max_seq_len=512,
        rnn_width=128 if cfg.rnn_width else None,
        rnn_heads=min(cfg.rnn_heads, 4) if cfg.rnn_heads > 1 else cfg.rnn_heads,
        window=64,
        encoder_layers=2 if cfg.encoder_layers else 0,
        prefix_len=16 if cfg.frontend != "none" else 0,
        moe=moe,
    )
