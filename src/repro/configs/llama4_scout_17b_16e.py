"""Llama-4-Scout-17B-16E — MoE decoder: 16 routed experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
Every layer carries a MoE FFN (top-1 of 16 routed + 1 always-on shared
expert, both width 8192). Early-fusion multimodality is out of scope for the
text backbone cells (noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    max_seq_len=131072,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=16, top_k=1, expert_d_ff=8192,
                  num_shared=1, shared_d_ff=8192),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
