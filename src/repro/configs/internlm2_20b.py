"""InternLM2-20B — dense GQA decoder.

[arXiv:2403.17297; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    tie_embeddings=False,
    source="arXiv:2403.17297; hf",
)
