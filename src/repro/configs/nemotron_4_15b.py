"""Nemotron-4-15B — dense GQA decoder with squared-ReLU MLP.

[arXiv:2402.16819; unverified]
Squared-ReLU (relu2) MLP and LayerNorm per the Nemotron-4 report.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    head_dim=128,
    mlp="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    max_seq_len=4096,
    tie_embeddings=False,
    source="arXiv:2402.16819; unverified",
)
