"""InternVL2-26B — VLM: InternViT-6B (stub) + InternLM2-20B language backbone.

[arXiv:2404.16821; hf-verified]
The vision tower is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings (post pixel-shuffle, post MLP-projector) at
d_model. The 48-layer InternLM2 backbone is fully implemented; vocab is the
92553-entry VLM-extended table.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    tie_embeddings=False,
    frontend="vision",
    prefix_len=256,
    source="arXiv:2404.16821; hf",
)
