"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, MoEConfig, ParallelConfig,
                                ShapeConfig, SHAPES, TrainConfig,
                                shape_applicable, smoke_config)

_MODULES = {
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_16e",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}
# accept both spellings of the llama4 id
_MODULES["llama4-scout-17b-16e"] = _MODULES["llama4-scout-17b-a16e"]


def list_archs() -> List[str]:
    return [k for k in _MODULES if k != "llama4-scout-17b-16e"]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in list_archs()}
