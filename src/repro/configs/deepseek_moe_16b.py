"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf-verified]
Layer 0 is a dense FFN (width 10944) per the DeepSeekMoE config
(n_dense_head=1); layers 1..27 use 64 fine-grained routed experts (width
1408, top-6) plus 2 shared experts (width 1408 each, fused to 2816).
MHA (kv=16).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,               # dense head layer width (assignment lists the
                              # expert width 1408 — see moe.expert_d_ff)
    vocab=102400,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    max_seq_len=16384,
    tie_embeddings=False,
    n_dense_head=1,
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared=2, shared_d_ff=1408),
    source="arXiv:2401.06066; hf",
)
