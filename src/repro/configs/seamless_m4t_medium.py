"""SeamlessM4T-medium backbone — encoder-decoder, multimodal (audio stub).

[arXiv:2308.11596; hf-verified]
The speech frontend (w2v-BERT conformer feature extractor) is a STUB per the
assignment: input_specs() provides precomputed frame embeddings at d_model.
The transformer backbone (12L bidirectional encoder + 12L causal decoder with
cross-attention, MHA kv=16) is fully implemented.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec_audio",
    n_layers=12,              # decoder depth
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    mlp="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    max_seq_len=4096,
    tie_embeddings=False,
    frontend="audio",
    source="arXiv:2308.11596; hf",
)
