"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay.

[arXiv:2404.05892; hf-verified]
32 layers of time-mix (matrix-valued state per 64-dim head, decay
w_t = exp(-exp(w0 + lora(x_t)))) + channel-mix (squared-ReLU, width 8960).
Constant-size state -> runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,               # bookkeeping: rnn heads of size 64
    n_kv_heads=0,             # attention-free
    d_ff=8960,
    vocab=65536,
    head_dim=64,
    mlp="relu2",
    norm="layernorm",
    max_seq_len=1_048_576,
    tie_embeddings=False,
    block_pattern=("rwkv",),
    rnn_heads=40,
    sub_quadratic=True,
    source="arXiv:2404.05892; hf",
)
